//! Coordinator→node connection reuse for the proxy hot path.
//!
//! Two pieces:
//!
//! * [`NodePool`] — a per-address stash of idle keep-alive TCP connections.
//!   A proxy attempt checks one out instead of dialing; a connection goes
//!   back in only when the previous response ended at a clean framing
//!   boundary, so a checked-out stream is always positioned at the start
//!   of a request/response exchange. Nodes reap silent connections after a
//!   few seconds, so the pool discards entries older than [`MAX_IDLE_AGE`]
//!   on checkout rather than handing the caller a half-dead socket.
//!
//! * [`ChunkFrameScanner`] — an incremental scanner over the upstream's
//!   chunked transfer coding that lets the coordinator forward SSE bytes
//!   to the client *verbatim*: no per-chunk decode, no re-framing through
//!   a second `ChunkedWriter`. The scanner only marks byte ranges that end
//!   at a complete chunk-frame boundary as forwardable, which keeps two
//!   invariants the proxy relies on: the client never sees a torn frame
//!   (so a terminal `service_unavailable` event can be injected cleanly if
//!   the node dies mid-stream), and the terminal `0\r\n\r\n` passes through
//!   unmodified to end the client's response exactly where the node's did.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Idle connections kept per node address. The proxy gate caps global
/// concurrency far above this, but one node rarely needs more parked
/// sockets than its worker count.
const MAX_IDLE_PER_NODE: usize = 16;

/// Gateway/node ingress reaps connections silent for ~5s; discard pooled
/// entries comfortably before that so checkout never returns a socket the
/// remote has already closed under normal operation.
const MAX_IDLE_AGE: Duration = Duration::from_secs(3);

/// Upper bound on one chunk frame (size line + payload). SSE events are
/// token deltas — anything near this is a protocol violation upstream.
const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Longest size/trailer line the scanner will buffer before declaring the
/// stream malformed.
const MAX_LINE_BYTES: usize = 256;

#[derive(Debug, Default)]
pub struct NodePool {
    idle: Mutex<HashMap<String, Vec<(TcpStream, Instant)>>>,
}

impl NodePool {
    pub fn new() -> NodePool {
        NodePool::default()
    }

    /// Pop a fresh-enough idle connection for `addr`, discarding any that
    /// sat past [`MAX_IDLE_AGE`].
    pub fn checkout(&self, addr: &str) -> Option<TcpStream> {
        let mut idle = self.idle.lock().unwrap();
        let stash = idle.get_mut(addr)?;
        while let Some((stream, parked)) = stash.pop() {
            if parked.elapsed() <= MAX_IDLE_AGE {
                return Some(stream);
            }
            // too old: likely reaped by the node's idle sweep — drop it
        }
        None
    }

    /// Park a connection whose previous response ended at a clean framing
    /// boundary.
    pub fn checkin(&self, addr: &str, stream: TcpStream) {
        let mut idle = self.idle.lock().unwrap();
        let stash = idle.entry(addr.to_string()).or_default();
        if stash.len() < MAX_IDLE_PER_NODE {
            stash.push((stream, Instant::now()));
        }
    }

    /// Drop every idle connection to `addr` — called when the coordinator
    /// declares the node dead so no attempt wastes a retry on its corpses.
    pub fn purge(&self, addr: &str) {
        self.idle.lock().unwrap().remove(addr);
    }

    /// Idle connections across all nodes (feeds the pool gauge).
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().values().map(Vec::len).sum()
    }
}

/// What one [`ChunkFrameScanner::push`] made forwardable.
///
/// Wire order is `carry_flush` then `emit`: `carry_flush` holds bytes of a
/// frame that started in an earlier push and completed in this one, `emit`
/// borrows the prefix of *this* push's input that ends at the last complete
/// frame boundary. Bytes past that boundary are carried internally until a
/// later push completes their frame.
#[derive(Debug)]
pub struct Scan<'a> {
    pub carry_flush: Vec<u8>,
    pub emit: &'a [u8],
    /// data frames (chunk size > 0) completed by this push
    pub data_frames: usize,
    /// the terminal `0`-size frame (plus trailer end) completed
    pub terminal: bool,
}

#[derive(Debug)]
enum ScanState {
    /// accumulating a chunk-size line up to its `\n`
    SizeLine { line: Vec<u8> },
    /// inside a data chunk payload; `remaining` includes the trailing CRLF
    Payload { remaining: usize },
    /// after the `0`-size line: trailer lines until the blank line
    Trailers { line: Vec<u8> },
    /// terminal frame fully seen — the response is over
    Done,
}

/// Incremental scanner over an HTTP/1.1 chunked body that reports, per
/// feed, which input bytes form *complete* chunk frames. The caller
/// forwards exactly those bytes; partial frames are held internally so the
/// downstream writer only ever sees whole frames.
#[derive(Debug)]
pub struct ChunkFrameScanner {
    state: ScanState,
    carry: Vec<u8>,
}

impl Default for ChunkFrameScanner {
    fn default() -> Self {
        ChunkFrameScanner::new()
    }
}

impl ChunkFrameScanner {
    pub fn new() -> ChunkFrameScanner {
        ChunkFrameScanner {
            state: ScanState::SizeLine { line: Vec::new() },
            carry: Vec::new(),
        }
    }

    /// True once the terminal frame was consumed with nothing left over —
    /// the connection is positioned at a clean response boundary and safe
    /// to return to the pool.
    pub fn is_clean(&self) -> bool {
        matches!(self.state, ScanState::Done) && self.carry.is_empty()
    }

    /// Advance the scanner over `input`.
    pub fn push<'a>(&mut self, input: &'a [u8]) -> Result<Scan<'a>, String> {
        let mut data_frames = 0usize;
        let mut terminal = false;
        let mut last_boundary: Option<usize> = None;
        let mut i = 0usize;
        while i < input.len() && !terminal {
            match &mut self.state {
                ScanState::SizeLine { line } => {
                    let b = input[i];
                    i += 1;
                    line.push(b);
                    if b == b'\n' {
                        let size = parse_size_line(line)?;
                        self.state = if size == 0 {
                            ScanState::Trailers { line: Vec::new() }
                        } else {
                            // fold the payload's trailing CRLF into the count
                            ScanState::Payload { remaining: size + 2 }
                        };
                    } else if line.len() > MAX_LINE_BYTES {
                        return Err("chunk size line too long".to_string());
                    }
                }
                ScanState::Payload { remaining } => {
                    let take = (*remaining).min(input.len() - i);
                    i += take;
                    *remaining -= take;
                    if *remaining == 0 {
                        self.state = ScanState::SizeLine { line: Vec::new() };
                        data_frames += 1;
                        last_boundary = Some(i);
                    }
                }
                ScanState::Trailers { line } => {
                    let b = input[i];
                    i += 1;
                    line.push(b);
                    if b == b'\n' {
                        if line == b"\r\n" || line == b"\n" {
                            self.state = ScanState::Done;
                            terminal = true;
                            last_boundary = Some(i);
                        } else {
                            line.clear();
                        }
                    } else if line.len() > MAX_LINE_BYTES {
                        return Err("chunk trailer line too long".to_string());
                    }
                }
                ScanState::Done => {
                    return Err("bytes after terminal chunk".to_string());
                }
            }
        }
        match last_boundary {
            Some(b) => {
                let carry_flush = std::mem::take(&mut self.carry);
                self.carry.extend_from_slice(&input[b..]);
                Ok(Scan {
                    carry_flush,
                    emit: &input[..b],
                    data_frames,
                    terminal,
                })
            }
            None => {
                self.carry.extend_from_slice(input);
                if self.carry.len() > MAX_FRAME_BYTES {
                    return Err("chunk frame exceeds relay cap".to_string());
                }
                Ok(Scan {
                    carry_flush: Vec::new(),
                    emit: &input[..0],
                    data_frames: 0,
                    terminal: false,
                })
            }
        }
    }
}

/// Parse one `\n`-terminated chunk-size line (chunk extensions after `;`
/// are tolerated and ignored).
fn parse_size_line(line: &[u8]) -> Result<usize, String> {
    let text = std::str::from_utf8(line)
        .map_err(|_| "non-utf8 chunk size line".to_string())?
        .trim_end_matches(['\r', '\n']);
    let size_part = text.split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_part, 16)
        .map_err(|_| format!("bad chunk size line: {text:?}"))?;
    if size > MAX_FRAME_BYTES {
        return Err(format!("chunk of {size} bytes exceeds relay cap"));
    }
    Ok(size)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(payload: &str) -> Vec<u8> {
        format!("{:x}\r\n{payload}\r\n", payload.len()).into_bytes()
    }

    /// Replays `wire` into a scanner in `step`-byte slices and returns the
    /// concatenation of everything it marked forwardable.
    fn relay_in_steps(wire: &[u8], step: usize) -> (Vec<u8>, usize, bool) {
        let mut scanner = ChunkFrameScanner::new();
        let mut out = Vec::new();
        let mut frames = 0;
        let mut terminal = false;
        for piece in wire.chunks(step) {
            let scan = scanner.push(piece).expect("well-formed wire");
            out.extend_from_slice(&scan.carry_flush);
            out.extend_from_slice(scan.emit);
            frames += scan.data_frames;
            terminal = terminal || scan.terminal;
        }
        (out, frames, terminal)
    }

    #[test]
    fn forwards_whole_stream_verbatim_at_any_split() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&frame("data: {\"token\":\"a\"}\n\n"));
        wire.extend_from_slice(&frame("data: {\"token\":\"b\"}\n\n"));
        wire.extend_from_slice(&frame("data: [DONE]\n\n"));
        wire.extend_from_slice(b"0\r\n\r\n");
        for step in [1, 2, 3, 7, 16, wire.len()] {
            let (out, frames, terminal) = relay_in_steps(&wire, step);
            assert_eq!(out, wire, "split {step}");
            assert_eq!(frames, 3, "split {step}");
            assert!(terminal, "split {step}");
        }
    }

    #[test]
    fn only_complete_frames_are_forwardable() {
        let mut scanner = ChunkFrameScanner::new();
        let wire = frame("data: hello\n\n");
        // everything but the last byte: nothing may be emitted yet
        let scan = scanner.push(&wire[..wire.len() - 1]).unwrap();
        assert!(scan.carry_flush.is_empty() && scan.emit.is_empty());
        assert_eq!(scan.data_frames, 0);
        // final byte completes the frame; carried bytes flush in wire order
        let scan2 = scanner.push(&wire[wire.len() - 1..]).unwrap();
        let mut got = scan2.carry_flush.clone();
        got.extend_from_slice(scan2.emit);
        assert_eq!(got, wire);
        assert_eq!(scan2.data_frames, 1);
        assert!(!scanner.is_clean(), "stream not terminated yet");
    }

    #[test]
    fn terminal_frame_marks_scanner_clean() {
        let mut scanner = ChunkFrameScanner::new();
        let mut wire = frame("data: bye\n\n");
        wire.extend_from_slice(b"0\r\n\r\n");
        let scan = scanner.push(&wire).unwrap();
        assert!(scan.terminal);
        assert_eq!(scan.emit, &wire[..]);
        assert!(scanner.is_clean());
        // anything after the terminal frame is a protocol violation
        assert!(scanner.push(b"x").is_err());
    }

    #[test]
    fn malformed_size_line_is_an_error() {
        let mut scanner = ChunkFrameScanner::new();
        assert!(scanner.push(b"zz\r\npayload\r\n").is_err());
    }

    #[test]
    fn pool_round_trips_and_purges() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let pool = NodePool::new();
        assert!(pool.checkout(&addr).is_none());
        let conn = TcpStream::connect(&addr).unwrap();
        pool.checkin(&addr, conn);
        assert_eq!(pool.idle_count(), 1);
        assert!(pool.checkout(&addr).is_some());
        assert_eq!(pool.idle_count(), 0);
        let conn = TcpStream::connect(&addr).unwrap();
        pool.checkin(&addr, conn);
        pool.purge(&addr);
        assert_eq!(pool.idle_count(), 0);
    }
}
