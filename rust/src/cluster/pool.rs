//! Coordinator→node connection reuse for the proxy hot path.
//!
//! Two pieces:
//!
//! * [`NodePool`] — a per-address stash of idle keep-alive TCP connections.
//!   A proxy attempt checks one out instead of dialing; a connection goes
//!   back in only when the previous response ended at a clean framing
//!   boundary, so a checked-out stream is always positioned at the start
//!   of a request/response exchange. Nodes reap silent connections after a
//!   few seconds, so the pool discards entries older than [`MAX_IDLE_AGE`]
//!   on checkout rather than handing the caller a half-dead socket.
//!
//! * [`CircuitBreaker`] — the per-node defense the chaos layer attacks: a
//!   rolling error/latency window with closed → open → half-open → closed
//!   transitions. The coordinator keeps one per registered node and uses
//!   it to *deroute* a slow-but-alive or error-spraying node without
//!   declaring it dead: an open breaker removes the node from dispatch,
//!   the cooldown admits a trickle of half-open probes, and enough probe
//!   successes restore it. Pure state machine (callers pass `Instant`s),
//!   so the transition logic is unit-testable without a clock.
//!
//! * [`ChunkFrameScanner`] — an incremental scanner over the upstream's
//!   chunked transfer coding that lets the coordinator forward SSE bytes
//!   to the client *verbatim*: no per-chunk decode, no re-framing through
//!   a second `ChunkedWriter`. The scanner only marks byte ranges that end
//!   at a complete chunk-frame boundary as forwardable, which keeps two
//!   invariants the proxy relies on: the client never sees a torn frame
//!   (so a terminal `service_unavailable` event can be injected cleanly if
//!   the node dies mid-stream), and the terminal `0\r\n\r\n` passes through
//!   unmodified to end the client's response exactly where the node's did.

use std::collections::{HashMap, VecDeque};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Idle connections kept per node address. The proxy gate caps global
/// concurrency far above this, but one node rarely needs more parked
/// sockets than its worker count.
const MAX_IDLE_PER_NODE: usize = 16;

/// Gateway/node ingress reaps connections silent for ~5s; discard pooled
/// entries comfortably before that so checkout never returns a socket the
/// remote has already closed under normal operation.
const MAX_IDLE_AGE: Duration = Duration::from_secs(3);

/// Upper bound on one chunk frame (size line + payload). SSE events are
/// token deltas — anything near this is a protocol violation upstream.
const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Longest size/trailer line the scanner will buffer before declaring the
/// stream malformed.
const MAX_LINE_BYTES: usize = 256;

#[derive(Debug, Default)]
pub struct NodePool {
    idle: Mutex<HashMap<String, Vec<(TcpStream, Instant)>>>,
}

impl NodePool {
    pub fn new() -> NodePool {
        NodePool::default()
    }

    /// Pop a fresh-enough idle connection for `addr`, discarding any that
    /// sat past [`MAX_IDLE_AGE`].
    pub fn checkout(&self, addr: &str) -> Option<TcpStream> {
        let mut idle = self.idle.lock().unwrap();
        let stash = idle.get_mut(addr)?;
        while let Some((stream, parked)) = stash.pop() {
            if parked.elapsed() <= MAX_IDLE_AGE {
                return Some(stream);
            }
            // too old: likely reaped by the node's idle sweep — drop it
        }
        None
    }

    /// Park a connection whose previous response ended at a clean framing
    /// boundary.
    pub fn checkin(&self, addr: &str, stream: TcpStream) {
        let mut idle = self.idle.lock().unwrap();
        let stash = idle.entry(addr.to_string()).or_default();
        if stash.len() < MAX_IDLE_PER_NODE {
            stash.push((stream, Instant::now()));
        }
    }

    /// Drop every idle connection to `addr` — called when the coordinator
    /// declares the node dead so no attempt wastes a retry on its corpses.
    pub fn purge(&self, addr: &str) {
        self.idle.lock().unwrap().remove(addr);
    }

    /// Idle connections across all nodes (feeds the pool gauge).
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().values().map(Vec::len).sum()
    }
}

/// What one [`ChunkFrameScanner::push`] made forwardable.
///
/// Wire order is `carry_flush` then `emit`: `carry_flush` holds bytes of a
/// frame that started in an earlier push and completed in this one, `emit`
/// borrows the prefix of *this* push's input that ends at the last complete
/// frame boundary. Bytes past that boundary are carried internally until a
/// later push completes their frame.
#[derive(Debug)]
pub struct Scan<'a> {
    pub carry_flush: Vec<u8>,
    pub emit: &'a [u8],
    /// data frames (chunk size > 0) completed by this push
    pub data_frames: usize,
    /// the terminal `0`-size frame (plus trailer end) completed
    pub terminal: bool,
}

#[derive(Debug)]
enum ScanState {
    /// accumulating a chunk-size line up to its `\n`
    SizeLine { line: Vec<u8> },
    /// inside a data chunk payload; `remaining` includes the trailing CRLF
    Payload { remaining: usize },
    /// after the `0`-size line: trailer lines until the blank line
    Trailers { line: Vec<u8> },
    /// terminal frame fully seen — the response is over
    Done,
}

/// Incremental scanner over an HTTP/1.1 chunked body that reports, per
/// feed, which input bytes form *complete* chunk frames. The caller
/// forwards exactly those bytes; partial frames are held internally so the
/// downstream writer only ever sees whole frames.
#[derive(Debug)]
pub struct ChunkFrameScanner {
    state: ScanState,
    carry: Vec<u8>,
}

impl Default for ChunkFrameScanner {
    fn default() -> Self {
        ChunkFrameScanner::new()
    }
}

impl ChunkFrameScanner {
    pub fn new() -> ChunkFrameScanner {
        ChunkFrameScanner {
            state: ScanState::SizeLine { line: Vec::new() },
            carry: Vec::new(),
        }
    }

    /// True once the terminal frame was consumed with nothing left over —
    /// the connection is positioned at a clean response boundary and safe
    /// to return to the pool.
    pub fn is_clean(&self) -> bool {
        matches!(self.state, ScanState::Done) && self.carry.is_empty()
    }

    /// Advance the scanner over `input`.
    pub fn push<'a>(&mut self, input: &'a [u8]) -> Result<Scan<'a>, String> {
        let mut data_frames = 0usize;
        let mut terminal = false;
        let mut last_boundary: Option<usize> = None;
        let mut i = 0usize;
        while i < input.len() && !terminal {
            match &mut self.state {
                ScanState::SizeLine { line } => {
                    let b = input[i];
                    i += 1;
                    line.push(b);
                    if b == b'\n' {
                        let size = parse_size_line(line)?;
                        self.state = if size == 0 {
                            ScanState::Trailers { line: Vec::new() }
                        } else {
                            // fold the payload's trailing CRLF into the count
                            ScanState::Payload { remaining: size + 2 }
                        };
                    } else if line.len() > MAX_LINE_BYTES {
                        return Err("chunk size line too long".to_string());
                    }
                }
                ScanState::Payload { remaining } => {
                    let take = (*remaining).min(input.len() - i);
                    i += take;
                    *remaining -= take;
                    if *remaining == 0 {
                        self.state = ScanState::SizeLine { line: Vec::new() };
                        data_frames += 1;
                        last_boundary = Some(i);
                    }
                }
                ScanState::Trailers { line } => {
                    let b = input[i];
                    i += 1;
                    line.push(b);
                    if b == b'\n' {
                        if line == b"\r\n" || line == b"\n" {
                            self.state = ScanState::Done;
                            terminal = true;
                            last_boundary = Some(i);
                        } else {
                            line.clear();
                        }
                    } else if line.len() > MAX_LINE_BYTES {
                        return Err("chunk trailer line too long".to_string());
                    }
                }
                ScanState::Done => {
                    return Err("bytes after terminal chunk".to_string());
                }
            }
        }
        match last_boundary {
            Some(b) => {
                let carry_flush = std::mem::take(&mut self.carry);
                self.carry.extend_from_slice(&input[b..]);
                Ok(Scan {
                    carry_flush,
                    emit: &input[..b],
                    data_frames,
                    terminal,
                })
            }
            None => {
                self.carry.extend_from_slice(input);
                if self.carry.len() > MAX_FRAME_BYTES {
                    return Err("chunk frame exceeds relay cap".to_string());
                }
                Ok(Scan {
                    carry_flush: Vec::new(),
                    emit: &input[..0],
                    data_frames: 0,
                    terminal: false,
                })
            }
        }
    }
}

/// Tuning of one per-node [`CircuitBreaker`].
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    /// whether the breaker participates in routing at all
    pub enabled: bool,
    /// rolling outcome window, in samples
    pub window: usize,
    /// evidence floor: no trip before this many samples are in the window
    pub min_samples: usize,
    /// error fraction over the window that opens the breaker
    pub error_threshold: f64,
    /// mean latency over the window that opens the breaker even with
    /// all-2xx outcomes — the "slow-but-alive" axis (ZERO disables it)
    pub latency_threshold: Duration,
    /// how long an open breaker blocks dispatch before probing
    pub cooldown: Duration,
    /// successful half-open probes required to close again; also the
    /// concurrent probe budget while half-open
    pub half_open_probes: usize,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            enabled: true,
            window: 20,
            min_samples: 8,
            error_threshold: 0.5,
            latency_threshold: Duration::ZERO,
            cooldown: Duration::from_secs(5),
            half_open_probes: 3,
        }
    }
}

/// Where a breaker is in its lifecycle. Gauge encoding is
/// severity-ordered: closed 0, half-open 1, open 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    HalfOpen,
    Open,
}

impl BreakerState {
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half_open",
            BreakerState::Open => "open",
        }
    }

    pub fn gauge(self) -> f64 {
        match self {
            BreakerState::Closed => 0.0,
            BreakerState::HalfOpen => 1.0,
            BreakerState::Open => 2.0,
        }
    }
}

/// A state change worth a metrics counter bump and a flight-recorder
/// entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerTransition {
    /// closed/half-open → open
    Opened,
    /// open → half-open (cooldown elapsed, probing begins)
    HalfOpened,
    /// half-open → closed (probes succeeded)
    Closed,
}

impl BreakerTransition {
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerTransition::Opened => "open",
            BreakerTransition::HalfOpened => "half_open",
            BreakerTransition::Closed => "close",
        }
    }
}

/// Per-node circuit breaker: rolling error/latency window, closed →
/// open → half-open → closed. All methods take `now` explicitly so tests
/// drive the clock.
#[derive(Debug, Clone)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    /// (ok, latency) outcomes, newest at the back, capped at cfg.window
    window: VecDeque<(bool, Duration)>,
    opened_at: Option<Instant>,
    probes_issued: usize,
    probe_successes: usize,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: BreakerState::Closed,
            window: VecDeque::new(),
            opened_at: None,
            probes_issued: 0,
            probe_successes: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Non-consuming routing check: would [`CircuitBreaker::allow`]
    /// refuse right now? Exclusion sets are built from this so a
    /// half-open node's probe budget is only spent on requests actually
    /// dispatched to it, never on requests that route elsewhere.
    pub fn would_block(&self, now: Instant) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        match self.state {
            BreakerState::Closed => false,
            BreakerState::Open => self
                .opened_at
                .map(|t| now.saturating_duration_since(t) < self.cfg.cooldown)
                .unwrap_or(false),
            BreakerState::HalfOpen => self.probes_issued >= self.cfg.half_open_probes.max(1),
        }
    }

    /// Error fraction over the current window (0 when empty).
    pub fn error_fraction(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        let errs = self.window.iter().filter(|(ok, _)| !ok).count();
        errs as f64 / self.window.len() as f64
    }

    /// Mean latency over the current window.
    pub fn mean_latency(&self) -> Duration {
        if self.window.is_empty() {
            return Duration::ZERO;
        }
        let total: Duration = self.window.iter().map(|(_, d)| *d).sum();
        total / self.window.len() as u32
    }

    /// One-line evidence summary for decision records.
    pub fn evidence(&self) -> String {
        format!(
            "err={:.2} mean_latency_ms={:.0} samples={}",
            self.error_fraction(),
            self.mean_latency().as_secs_f64() * 1e3,
            self.window.len()
        )
    }

    /// May a request be dispatched to this node right now? Open breakers
    /// say no until the cooldown elapses (then flip to half-open and
    /// admit this call as the first probe); half-open breakers admit up
    /// to the probe budget.
    pub fn allow(&mut self, now: Instant) -> (bool, Option<BreakerTransition>) {
        if !self.cfg.enabled {
            return (true, None);
        }
        match self.state {
            BreakerState::Closed => (true, None),
            BreakerState::Open => {
                let elapsed = self
                    .opened_at
                    .map(|t| now.saturating_duration_since(t))
                    .unwrap_or(Duration::ZERO);
                if elapsed >= self.cfg.cooldown {
                    self.state = BreakerState::HalfOpen;
                    self.probes_issued = 1;
                    self.probe_successes = 0;
                    (true, Some(BreakerTransition::HalfOpened))
                } else {
                    (false, None)
                }
            }
            BreakerState::HalfOpen => {
                if self.probes_issued < self.cfg.half_open_probes.max(1) {
                    self.probes_issued += 1;
                    (true, None)
                } else {
                    (false, None)
                }
            }
        }
    }

    /// Feed one request outcome (proxy attempt or heartbeat) into the
    /// window and run the transition rules.
    pub fn record(
        &mut self,
        ok: bool,
        latency: Duration,
        now: Instant,
    ) -> Option<BreakerTransition> {
        if !self.cfg.enabled {
            return None;
        }
        self.window.push_back((ok, latency));
        while self.window.len() > self.cfg.window.max(1) {
            self.window.pop_front();
        }
        match self.state {
            BreakerState::Closed => {
                if self.window.len() < self.cfg.min_samples.max(1) {
                    return None;
                }
                let slow = self.cfg.latency_threshold > Duration::ZERO
                    && self.mean_latency() >= self.cfg.latency_threshold;
                if self.error_fraction() >= self.cfg.error_threshold || slow {
                    self.open(now);
                    return Some(BreakerTransition::Opened);
                }
                None
            }
            BreakerState::HalfOpen => {
                if !ok {
                    // one failed probe re-opens: the node is still sick
                    self.open(now);
                    return Some(BreakerTransition::Opened);
                }
                self.probe_successes += 1;
                if self.probe_successes >= self.cfg.half_open_probes.max(1) {
                    self.state = BreakerState::Closed;
                    self.opened_at = None;
                    // fresh evidence only: pre-open samples must not
                    // immediately re-trip a recovered node
                    self.window.clear();
                    return Some(BreakerTransition::Closed);
                }
                None
            }
            // late results from requests in flight when the breaker
            // opened: keep them in the window, no transition
            BreakerState::Open => None,
        }
    }

    fn open(&mut self, now: Instant) {
        self.state = BreakerState::Open;
        self.opened_at = Some(now);
        self.probes_issued = 0;
        self.probe_successes = 0;
    }
}

/// Parse one `\n`-terminated chunk-size line (chunk extensions after `;`
/// are tolerated and ignored).
fn parse_size_line(line: &[u8]) -> Result<usize, String> {
    let text = std::str::from_utf8(line)
        .map_err(|_| "non-utf8 chunk size line".to_string())?
        .trim_end_matches(['\r', '\n']);
    let size_part = text.split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_part, 16)
        .map_err(|_| format!("bad chunk size line: {text:?}"))?;
    if size > MAX_FRAME_BYTES {
        return Err(format!("chunk of {size} bytes exceeds relay cap"));
    }
    Ok(size)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(payload: &str) -> Vec<u8> {
        format!("{:x}\r\n{payload}\r\n", payload.len()).into_bytes()
    }

    /// Replays `wire` into a scanner in `step`-byte slices and returns the
    /// concatenation of everything it marked forwardable.
    fn relay_in_steps(wire: &[u8], step: usize) -> (Vec<u8>, usize, bool) {
        let mut scanner = ChunkFrameScanner::new();
        let mut out = Vec::new();
        let mut frames = 0;
        let mut terminal = false;
        for piece in wire.chunks(step) {
            let scan = scanner.push(piece).expect("well-formed wire");
            out.extend_from_slice(&scan.carry_flush);
            out.extend_from_slice(scan.emit);
            frames += scan.data_frames;
            terminal = terminal || scan.terminal;
        }
        (out, frames, terminal)
    }

    #[test]
    fn forwards_whole_stream_verbatim_at_any_split() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&frame("data: {\"token\":\"a\"}\n\n"));
        wire.extend_from_slice(&frame("data: {\"token\":\"b\"}\n\n"));
        wire.extend_from_slice(&frame("data: [DONE]\n\n"));
        wire.extend_from_slice(b"0\r\n\r\n");
        for step in [1, 2, 3, 7, 16, wire.len()] {
            let (out, frames, terminal) = relay_in_steps(&wire, step);
            assert_eq!(out, wire, "split {step}");
            assert_eq!(frames, 3, "split {step}");
            assert!(terminal, "split {step}");
        }
    }

    #[test]
    fn only_complete_frames_are_forwardable() {
        let mut scanner = ChunkFrameScanner::new();
        let wire = frame("data: hello\n\n");
        // everything but the last byte: nothing may be emitted yet
        let scan = scanner.push(&wire[..wire.len() - 1]).unwrap();
        assert!(scan.carry_flush.is_empty() && scan.emit.is_empty());
        assert_eq!(scan.data_frames, 0);
        // final byte completes the frame; carried bytes flush in wire order
        let scan2 = scanner.push(&wire[wire.len() - 1..]).unwrap();
        let mut got = scan2.carry_flush.clone();
        got.extend_from_slice(scan2.emit);
        assert_eq!(got, wire);
        assert_eq!(scan2.data_frames, 1);
        assert!(!scanner.is_clean(), "stream not terminated yet");
    }

    #[test]
    fn terminal_frame_marks_scanner_clean() {
        let mut scanner = ChunkFrameScanner::new();
        let mut wire = frame("data: bye\n\n");
        wire.extend_from_slice(b"0\r\n\r\n");
        let scan = scanner.push(&wire).unwrap();
        assert!(scan.terminal);
        assert_eq!(scan.emit, &wire[..]);
        assert!(scanner.is_clean());
        // anything after the terminal frame is a protocol violation
        assert!(scanner.push(b"x").is_err());
    }

    #[test]
    fn malformed_size_line_is_an_error() {
        let mut scanner = ChunkFrameScanner::new();
        assert!(scanner.push(b"zz\r\npayload\r\n").is_err());
    }

    #[test]
    fn would_block_mirrors_allow_without_consuming_probes() {
        let mut b = fast_breaker();
        let t0 = Instant::now();
        for _ in 0..6 {
            b.record(false, Duration::from_millis(1), t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        assert!(b.would_block(t0));
        // cooldown elapsed: routable again, but the read-only check must
        // not flip to half-open or admit a probe by itself
        let later = t0 + Duration::from_millis(60);
        assert!(!b.would_block(later));
        assert_eq!(b.state(), BreakerState::Open);
        let (ok, tr) = b.allow(later);
        assert!(ok);
        assert_eq!(tr, Some(BreakerTransition::HalfOpened));
    }

    fn fast_breaker() -> CircuitBreaker {
        CircuitBreaker::new(BreakerConfig {
            window: 10,
            min_samples: 4,
            error_threshold: 0.5,
            cooldown: Duration::from_millis(50),
            half_open_probes: 2,
            ..BreakerConfig::default()
        })
    }

    #[test]
    fn breaker_needs_evidence_before_opening() {
        let mut b = fast_breaker();
        let now = Instant::now();
        // three straight failures: below the min_samples floor, no trip
        for _ in 0..3 {
            assert_eq!(b.record(false, Duration::from_millis(5), now), None);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        // fourth failure crosses the floor at 100% errors
        assert_eq!(
            b.record(false, Duration::from_millis(5), now),
            Some(BreakerTransition::Opened)
        );
        assert_eq!(b.state(), BreakerState::Open);
        let (allowed, _) = b.allow(now);
        assert!(!allowed, "open breaker must block dispatch");
    }

    #[test]
    fn breaker_recovers_through_half_open() {
        let mut b = fast_breaker();
        let t0 = Instant::now();
        for _ in 0..4 {
            b.record(false, Duration::from_millis(5), t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        // cooldown not elapsed: still blocked
        assert!(!b.allow(t0 + Duration::from_millis(10)).0);
        // cooldown elapsed: half-open, this call is probe #1
        let (allowed, tr) = b.allow(t0 + Duration::from_millis(60));
        assert!(allowed);
        assert_eq!(tr, Some(BreakerTransition::HalfOpened));
        // probe budget is 2: one more allowed, then blocked
        assert!(b.allow(t0 + Duration::from_millis(61)).0);
        assert!(!b.allow(t0 + Duration::from_millis(62)).0);
        // two probe successes close it and clear the stale window
        assert_eq!(b.record(true, Duration::from_millis(5), t0), None);
        assert_eq!(
            b.record(true, Duration::from_millis(5), t0),
            Some(BreakerTransition::Closed)
        );
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.error_fraction(), 0.0, "window cleared on close");
    }

    #[test]
    fn failed_probe_reopens() {
        let mut b = fast_breaker();
        let t0 = Instant::now();
        for _ in 0..4 {
            b.record(false, Duration::from_millis(5), t0);
        }
        let t1 = t0 + Duration::from_millis(60);
        assert!(b.allow(t1).0);
        assert_eq!(
            b.record(false, Duration::from_millis(5), t1),
            Some(BreakerTransition::Opened)
        );
        assert_eq!(b.state(), BreakerState::Open);
        // the fresh open restarts the cooldown from t1
        assert!(!b.allow(t1 + Duration::from_millis(10)).0);
        assert!(b.allow(t1 + Duration::from_millis(60)).0);
    }

    #[test]
    fn slow_but_alive_trips_latency_threshold() {
        let mut b = CircuitBreaker::new(BreakerConfig {
            window: 10,
            min_samples: 4,
            error_threshold: 0.5,
            latency_threshold: Duration::from_millis(100),
            ..BreakerConfig::default()
        });
        let now = Instant::now();
        // all-2xx outcomes, but the rolling mean latency crosses 100ms
        for i in 0..3 {
            assert_eq!(b.record(true, Duration::from_millis(200), now), None, "i={i}");
        }
        assert_eq!(
            b.record(true, Duration::from_millis(200), now),
            Some(BreakerTransition::Opened)
        );
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn disabled_breaker_never_blocks() {
        let mut b = CircuitBreaker::new(BreakerConfig {
            enabled: false,
            min_samples: 1,
            window: 2,
            ..BreakerConfig::default()
        });
        let now = Instant::now();
        for _ in 0..20 {
            assert_eq!(b.record(false, Duration::from_secs(5), now), None);
            assert!(b.allow(now).0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn good_traffic_keeps_breaker_closed() {
        let mut b = fast_breaker();
        let now = Instant::now();
        for _ in 0..50 {
            assert_eq!(b.record(true, Duration::from_millis(10), now), None);
        }
        // sporadic failures below the threshold: stays closed
        for _ in 0..50 {
            b.record(true, Duration::from_millis(10), now);
            b.record(true, Duration::from_millis(10), now);
            b.record(true, Duration::from_millis(10), now);
            assert_eq!(b.record(false, Duration::from_millis(10), now), None);
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn pool_round_trips_and_purges() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let pool = NodePool::new();
        assert!(pool.checkout(&addr).is_none());
        let conn = TcpStream::connect(&addr).unwrap();
        pool.checkin(&addr, conn);
        assert_eq!(pool.idle_count(), 1);
        assert!(pool.checkout(&addr).is_some());
        assert_eq!(pool.idle_count(), 0);
        let conn = TcpStream::connect(&addr).unwrap();
        pool.checkin(&addr, conn);
        pool.purge(&addr);
        assert_eq!(pool.idle_count(), 0);
    }
}
