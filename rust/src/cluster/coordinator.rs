//! The cluster coordinator: the ingress owner of the distributed plane
//! (`enova serve-http --cluster`). Clients speak to it exactly as they
//! would to a single-node gateway — same OpenAI endpoints, same SSE wire
//! format, same admission 429s — and it places every request on a node
//! via node-aware weighted least-loaded routing, retrying on another node
//! when the chosen one dies or sheds, so a node failure is a routing
//! event rather than an error budget event. Between healthy and dead sits
//! *degraded*: every node carries a [`super::pool::CircuitBreaker`] over
//! its rolling proxy outcomes, so a slow-but-alive or error-spewing node
//! is derouted (open → half-open probes → closed) while its heartbeats
//! and replicas stay up — exported as `enova_cluster_breaker_*` metrics
//! and recorded in `/v1/debug/decisions`.
//!
//! Three background loops:
//!
//! * **heartbeat** — polls every registered node's `/v1/admin/status`,
//!   flips health after consecutive misses, and rebuilds the node router
//!   (weights ∝ live replicas) on every sweep.
//! * **supervisor** — the single-node monitor → detect → act loop run
//!   cluster-wide: a [`ZscoreDetector`] over cluster-mean Table II rows,
//!   the queue-wait guard, and the forecast planner
//!   ([`crate::forecast::replicas_for_cluster_rate`] over per-node
//!   replica capacities). Decisions become *placements*: which node gets
//!   the next replica is [`super::placement`]'s bin-packing +
//!   anti-affinity call; drains pick the most-fragmented node.
//!   A dead node's replicas are backfilled on survivors — the supervisor
//!   tracks the replica count it wants, not where it happens to live.
//! * **ingress** — the same sharded reactor as the gateway
//!   ([`crate::gateway::reactor`]), with the legacy thread-per-connection
//!   pool behind [`IngressMode::Threaded`]. The proxy hop reuses
//!   keep-alive node connections from a [`super::pool::NodePool`] and
//!   relays SSE chunk frames zero-copy.

use super::metrics::{render_prometheus, ClusterMetrics, NodeSample};
use super::placement;
use super::pool::{
    BreakerConfig, BreakerTransition, ChunkFrameScanner, CircuitBreaker, NodePool,
};
use super::proto::{
    AdminError, AdminNodeScaleResponse, DebugExportResponse, MigrationListResponse,
    MigrationPhase, MigrationRequest, MigrationStatus, NodeAnnounce, NodeStatus,
    ScaleDirection as AdminScaleDirection, SnapshotAction, SnapshotListResponse,
    SnapshotRequest,
};
use crate::deployer::NodeInventory;
use crate::detect::{ScaleDirection, ZscoreDetector};
use crate::forecast::{replicas_for_cluster_rate, ForecastConfig, Forecaster};
use crate::gateway::admission::{
    AdmissionGate, SloTier, TenantRegistry, TenantSpec, TokenBucket,
};
use crate::gateway::http;
use crate::gateway::loadgen::{self, read_chunk, read_response_head};
use crate::gateway::openai;
use crate::gateway::reactor;
use crate::gateway::sse::write_sse_head;
use crate::gateway::IngressMode;
use crate::gateway::supervisor::{ForecastPolicy, Streaks, Trigger};
use crate::metrics::Frame;
use crate::trace::{
    ActiveTrace, DecisionRecorder, SpanKind, TraceContext, TraceRecorder, TraceSettings,
    PHASE_ADMISSION,
};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Largest upstream response body the proxy will buffer (unary paths;
/// streams are relayed chunk-by-chunk and never buffered).
const MAX_PROXY_BODY: usize = 16 * 1024 * 1024;
/// Timeout on one heartbeat poll.
const HEARTBEAT_RPC_TIMEOUT: Duration = Duration::from_secs(2);
/// Timeout on one scale RPC — bounded by the node's cold engine init.
const SCALE_RPC_TIMEOUT: Duration = Duration::from_secs(310);
/// Minimum per-replica capacity evidence (requests/second) before the
/// forecast planner converts predictions into placements — the same floor
/// the single-node planner applies.
const MIN_CAPACITY_EVIDENCE: f64 = 0.05;

/// Cluster-wide scaling policy: the [`crate::gateway::supervisor`] knobs,
/// re-scoped from one process's replicas to the fleet.
#[derive(Debug, Clone)]
pub struct ClusterPolicy {
    pub sample_interval: Duration,
    pub calib_samples: usize,
    pub patience: usize,
    pub cooldown: Duration,
    /// cluster-wide replica floor/ceiling (nodes also enforce their own)
    pub min_replicas: usize,
    pub max_replicas: usize,
    pub queue_wait_budget: Duration,
    pub detector_scaling: bool,
    pub forecast: Option<ForecastPolicy>,
    /// opportunistic rebalancing: when the supervisor is otherwise idle
    /// (no scale work, cooldowns clear), live-migrate a replica off the
    /// most-fragmented node onto the placement policy's pick
    pub defrag: bool,
}

impl Default for ClusterPolicy {
    fn default() -> Self {
        ClusterPolicy {
            sample_interval: Duration::from_secs(1),
            calib_samples: 30,
            patience: 3,
            cooldown: Duration::from_secs(30),
            min_replicas: 1,
            max_replicas: 8,
            queue_wait_budget: Duration::from_millis(500),
            detector_scaling: false,
            forecast: None,
            defrag: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub host: String,
    /// 0 = ephemeral (tests)
    pub port: u16,
    pub http_workers: usize,
    /// connection acceptance model; [`IngressMode::Reactor`] by default
    pub ingress: IngressMode,
    pub max_body_bytes: usize,
    /// admission bound on in-flight proxied requests (429 beyond)
    pub max_pending: usize,
    /// token-bucket refill, requests/second; 0 disables rate limiting
    pub rate_limit: f64,
    pub rate_burst: usize,
    pub heartbeat_interval: Duration,
    /// consecutive missed heartbeats before a node is declared dead
    pub node_timeout_beats: u32,
    /// per-request proxy deadline (per attempt)
    pub request_timeout: Duration,
    /// distinct nodes tried per request before answering 503
    pub dispatch_attempts: usize,
    pub policy: ClusterPolicy,
    /// request tracing: sample rate, slow-trace SLO, ring capacity
    pub trace: TraceSettings,
    /// tenant registry specs; empty = the built-in mixture tenants. The
    /// coordinator resolves tenants only for SLO-tier proxy steering —
    /// per-tenant admission and the cost ledger live on the nodes, which
    /// see the forwarded `x-enova-tenant` / `Authorization` headers.
    pub tenants: Vec<TenantSpec>,
    /// per-node circuit-breaker tuning: rolling error/latency windows on
    /// proxy outcomes that deroute a degraded node (open → half-open →
    /// closed) without declaring it dead
    pub breaker: BreakerConfig,
    /// serve the pre-v1 alias paths (`/cluster/status`, `/debug/*`).
    /// Default on for one release; aliases answer with `Deprecation` +
    /// `Sunset` headers and count into
    /// `enova_api_deprecated_requests_total`. Off ⇒ 410 Gone.
    pub legacy_api: bool,
    /// cadence of the coordinator's periodic per-node engine snapshots
    /// (the frames that back near-instant dead-node backfill and live
    /// migration). Zero disables capture.
    pub snapshot_interval: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            host: "127.0.0.1".into(),
            port: 0,
            http_workers: 64,
            ingress: IngressMode::Reactor,
            max_body_bytes: 1024 * 1024,
            max_pending: 1024,
            rate_limit: 0.0,
            rate_burst: 64,
            heartbeat_interval: Duration::from_millis(500),
            node_timeout_beats: 3,
            request_timeout: Duration::from_secs(120),
            dispatch_attempts: 3,
            policy: ClusterPolicy::default(),
            trace: TraceSettings::default(),
            tenants: Vec::new(),
            breaker: BreakerConfig::default(),
            legacy_api: true,
            snapshot_interval: Duration::from_secs(3),
        }
    }
}

/// One executed placement (scale-up) or drain (scale-down).
#[derive(Debug, Clone)]
pub struct PlacementEvent {
    /// seconds since coordinator start
    pub at: f64,
    pub node_id: String,
    /// spawned/promoted replica id for scale-ups, retired id for drains
    pub replica_id: u64,
    /// metric label: `forecast`, `detector`, `queue_wait`, `backfill`
    pub reason: &'static str,
    pub up: bool,
}

/// Cheap copy of the cluster supervisor's state for `/metrics` and tests.
#[derive(Debug, Clone, Default)]
pub struct ClusterSupervisorSnapshot {
    pub enabled: bool,
    pub calibrated: bool,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub target_replicas: usize,
    pub forecast_enabled: bool,
    pub last_forecast: f64,
    pub forecast_error: f64,
    pub forecast_degraded: bool,
    pub events: usize,
}

#[derive(Debug, Default)]
pub(super) struct ClusterSupervisorStatus {
    enabled: bool,
    calibrated: bool,
    scale_ups: u64,
    scale_downs: u64,
    forecast_enabled: bool,
    last_forecast: f64,
    forecast_error: f64,
    forecast_degraded: bool,
    events: Vec<PlacementEvent>,
}

/// One registered node as the coordinator tracks it.
#[derive(Debug, Clone)]
pub(super) struct NodeEntry {
    pub(super) announce: NodeAnnounce,
    pub(super) status: Option<NodeStatus>,
    pub(super) healthy: bool,
    pub(super) failures: u32,
    /// rolling proxy-outcome window; an open breaker deroutes the node
    /// while heartbeats keep running (degraded ≠ dead)
    pub(super) breaker: CircuitBreaker,
}

pub(super) struct CoordinatorState {
    pub(super) cfg: CoordinatorConfig,
    pub(super) nodes: RwLock<BTreeMap<String, NodeEntry>>,
    pub(super) router: RwLock<crate::router::NodeRouter>,
    /// tenant identities, for SLO-tier-aware proxy steering
    pub(super) tenants: Arc<TenantRegistry>,
    pub(super) gate: Arc<AdmissionGate>,
    pub(super) bucket: Option<Mutex<TokenBucket>>,
    /// idle keep-alive connections to nodes, reused across proxy attempts
    pub(super) pool: NodePool,
    pub(super) metrics: ClusterMetrics,
    pub(super) tracer: TraceRecorder,
    pub(super) decisions: DecisionRecorder,
    pub(super) supervisor: Mutex<ClusterSupervisorStatus>,
    /// replica count the supervisor wants cluster-wide; node death leaves
    /// it unchanged, which is exactly what makes backfill fire. 0 = not
    /// yet initialized from the first observation.
    pub(super) target_replicas: AtomicUsize,
    /// migration state machine records (`/v1/admin/migrations`)
    pub(super) migrations: super::migrate::MigrationRegistry,
    /// last periodic engine snapshot per node — a dead node's capacity is
    /// restored from here instead of cold-spawned
    pub(super) snapshots: Mutex<BTreeMap<String, super::migrate::StoredSnapshot>>,
    pub(super) started: Instant,
    pub(super) stop: AtomicBool,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    pub addr: SocketAddr,
    state: Arc<CoordinatorState>,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let supervisor_enabled = cfg.policy.detector_scaling || cfg.policy.forecast.is_some();
        let tenants = if cfg.tenants.is_empty() {
            TenantRegistry::with_defaults()
        } else {
            TenantRegistry::new(cfg.tenants.clone())
        };
        let state = Arc::new(CoordinatorState {
            nodes: RwLock::new(BTreeMap::new()),
            router: RwLock::new(crate::router::NodeRouter::new()),
            tenants,
            gate: AdmissionGate::new(cfg.max_pending),
            bucket: (cfg.rate_limit > 0.0)
                .then(|| Mutex::new(TokenBucket::new(cfg.rate_limit, cfg.rate_burst))),
            pool: NodePool::new(),
            metrics: ClusterMetrics::new(),
            tracer: TraceRecorder::new(cfg.trace.clone()),
            decisions: DecisionRecorder::new(256),
            supervisor: Mutex::new(ClusterSupervisorStatus {
                enabled: supervisor_enabled,
                forecast_enabled: cfg.policy.forecast.is_some(),
                ..ClusterSupervisorStatus::default()
            }),
            target_replicas: AtomicUsize::new(0),
            migrations: super::migrate::MigrationRegistry::new(),
            snapshots: Mutex::new(BTreeMap::new()),
            started: Instant::now(),
            stop: AtomicBool::new(false),
            cfg,
        });

        // connection fan-out, per the configured ingress mode (same
        // split as the gateway's)
        let mut threads = Vec::new();
        match state.cfg.ingress {
            IngressMode::Reactor => {
                // no stop-flag fast-exit in the handler: requests already
                // dispatched when a drain starts still run route() and
                // get well-formed responses
                let handler: reactor::Handler = {
                    let state = Arc::clone(&state);
                    Arc::new(move |stream: &mut TcpStream, req: &http::Request| {
                        let keep = req.keep_alive();
                        route(req, stream, &state).is_ok() && keep
                    })
                };
                let on_parse_error: reactor::ErrorResponder = Arc::new(|e| {
                    let body =
                        openai::to_wire(&openai::error_body("invalid_request_error", &e.message));
                    http::Response::json(e.status, body)
                });
                let stop: reactor::StopCheck = {
                    let state = Arc::clone(&state);
                    Arc::new(move || state.stop.load(Ordering::Acquire))
                };
                let rcfg = reactor::ReactorConfig {
                    shards: reactor::default_shards(),
                    handler_threads: state.cfg.http_workers.max(1),
                    max_body_bytes: state.cfg.max_body_bytes,
                    idle_timeout: Duration::from_secs(5),
                };
                let r = reactor::Reactor::start(
                    listener,
                    rcfg,
                    handler,
                    on_parse_error,
                    stop,
                    Arc::clone(&state.metrics.ingress),
                )?;
                threads.extend(r.into_threads());
            }
            IngressMode::Threaded => {
                // legacy: accept thread -> worker pool
                state
                    .metrics
                    .ingress
                    .handler_threads
                    .store(state.cfg.http_workers.max(1) as u64, Ordering::Release);
                let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
                let conn_rx = Arc::new(Mutex::new(conn_rx));
                {
                    let state = Arc::clone(&state);
                    threads.push(std::thread::spawn(move || {
                        accept_loop(listener, conn_tx, &state);
                    }));
                }
                for _ in 0..state.cfg.http_workers.max(1) {
                    let state = Arc::clone(&state);
                    let conn_rx = Arc::clone(&conn_rx);
                    threads.push(std::thread::spawn(move || loop {
                        if state.stop.load(Ordering::Acquire) {
                            break;
                        }
                        let next = conn_rx
                            .lock()
                            .unwrap()
                            .recv_timeout(Duration::from_millis(100));
                        match next {
                            Ok(stream) => {
                                handle_connection(stream, &state);
                                state.metrics.ingress.open.fetch_sub(1, Ordering::AcqRel);
                            }
                            Err(RecvTimeoutError::Timeout) => continue,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }));
                }
            }
        }
        {
            let state = Arc::clone(&state);
            threads.push(std::thread::spawn(move || heartbeat_loop(&state)));
        }
        {
            let state = Arc::clone(&state);
            threads.push(std::thread::spawn(move || supervisor_loop(&state)));
        }

        crate::info!(
            "cluster",
            "coordinator listening on http://{addr} ({} http workers, heartbeat {:?}, \
             supervisor {})",
            state.cfg.http_workers,
            state.cfg.heartbeat_interval,
            if supervisor_enabled { "on" } else { "backfill-only" }
        );
        Ok(Coordinator {
            addr,
            state,
            threads,
        })
    }

    pub fn addr_string(&self) -> String {
        self.addr.to_string()
    }

    /// Per-node snapshot rows (the `/metrics` view).
    pub fn nodes(&self) -> Vec<NodeSample> {
        node_samples(&self.state)
    }

    pub fn healthy_nodes(&self) -> usize {
        self.nodes().iter().filter(|n| n.healthy).count()
    }

    /// Live replicas across healthy nodes.
    pub fn total_replicas(&self) -> usize {
        self.nodes()
            .iter()
            .filter(|n| n.healthy)
            .map(|n| n.live_replicas)
            .sum()
    }

    /// Live replicas the coordinator believes one node has.
    pub fn replicas_on(&self, node_id: &str) -> usize {
        self.nodes()
            .iter()
            .find(|n| n.node_id == node_id)
            .map(|n| n.live_replicas)
            .unwrap_or(0)
    }

    /// Placements and drains the cluster supervisor executed, in order.
    pub fn placements(&self) -> Vec<PlacementEvent> {
        self.state.supervisor.lock().unwrap().events.clone()
    }

    /// Migration records, oldest first (the `/v1/admin/migrations` view).
    pub fn migrations(&self) -> Vec<MigrationStatus> {
        self.state.migrations.list()
    }

    /// Nodes whose engine snapshot the coordinator currently holds.
    pub fn snapshotted_nodes(&self) -> Vec<String> {
        self.state.snapshots.lock().unwrap().keys().cloned().collect()
    }

    /// Legacy-alias hits by path (test helper for the deprecation counter).
    pub fn deprecated_hits(&self, path: &str) -> u64 {
        self.state.metrics.deprecated_for(path)
    }

    pub fn supervisor_snapshot(&self) -> ClusterSupervisorSnapshot {
        supervisor_snapshot(&self.state)
    }

    /// Total scale-up placements by metric reason (test helper).
    pub fn placements_for(&self, reason: &str) -> u64 {
        self.state.metrics.placements_for(reason)
    }

    /// Coordinator-side trace records (proxy + retry spans), oldest first.
    pub fn traces(&self) -> Vec<crate::trace::TraceRecord> {
        self.state.tracer.traces()
    }

    /// The decision flight recorder: every placement/drain with its cause
    /// snapshot, oldest first.
    pub fn decisions(&self) -> Vec<crate::trace::Decision> {
        self.state.decisions.decisions()
    }

    /// Cluster-wide trace view: coordinator records with the node-side
    /// spans of the same trace ID merged in (the `/debug/traces` body).
    pub fn aggregated_traces(&self) -> Json {
        aggregated_traces(&self.state)
    }

    /// Block until `n` healthy, ready nodes are registered (true) or the
    /// timeout elapses (false).
    pub fn wait_for_nodes(&self, n: usize, timeout: Duration) -> bool {
        self.wait(timeout, || {
            self.nodes()
                .iter()
                .filter(|s| s.healthy && s.ready && s.live_replicas > 0)
                .count()
                >= n
        })
    }

    /// Block until the healthy fleet holds at least `n` live replicas.
    pub fn wait_for_replicas(&self, n: usize, timeout: Duration) -> bool {
        self.wait(timeout, || self.total_replicas() >= n)
    }

    fn wait(&self, timeout: Duration, ready: impl Fn() -> bool) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if ready() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        ready()
    }

    /// Stop all loops and join the threads. Nodes are left running — the
    /// coordinator owns routing, not node lifecycles.
    pub fn shutdown(self) {
        self.state.stop.store(true, Ordering::Release);
        for t in self.threads {
            let _ = t.join();
        }
    }

    /// Block forever serving (CLI path).
    pub fn serve_forever(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn supervisor_snapshot(state: &CoordinatorState) -> ClusterSupervisorSnapshot {
    let sup = state.supervisor.lock().unwrap();
    ClusterSupervisorSnapshot {
        enabled: sup.enabled,
        calibrated: sup.calibrated,
        scale_ups: sup.scale_ups,
        scale_downs: sup.scale_downs,
        target_replicas: state.target_replicas.load(Ordering::Acquire),
        forecast_enabled: sup.forecast_enabled,
        last_forecast: sup.last_forecast,
        forecast_error: sup.forecast_error,
        forecast_degraded: sup.forecast_degraded,
        events: sup.events.len(),
    }
}

pub(super) fn node_samples(state: &CoordinatorState) -> Vec<NodeSample> {
    let router = state.router.read().unwrap();
    state
        .nodes
        .read()
        .unwrap()
        .values()
        .map(|e| NodeSample {
            node_id: e.announce.node_id.clone(),
            healthy: e.healthy,
            ready: e.status.as_ref().map(|s| s.ready).unwrap_or(false),
            live_replicas: e.status.as_ref().map(|s| s.live_replicas).unwrap_or(0),
            warm_replicas: e.status.as_ref().map(|s| s.warm_replicas).unwrap_or(0),
            gpu_memory_total: e.announce.gpu_memory_total,
            gpu_memory_free: e
                .status
                .as_ref()
                .map(|s| s.gpu_memory_free)
                .unwrap_or(e.announce.gpu_memory_total),
            arrival_rps: e.status.as_ref().map(|s| s.arrival_rps).unwrap_or(0.0),
            queue_wait: e.status.as_ref().map(|s| s.queue_wait).unwrap_or(0.0),
            batch_rps: e.status.as_ref().map(|s| s.batch_rps).unwrap_or(0.0),
            inflight: router.inflight_of(&e.announce.node_id),
            breaker_state: e.breaker.state(),
        })
        .collect()
}

/// Rebuild the node router from the registry: healthy nodes, weighted by
/// live replica count (a node whose status is still unknown gets weight 1
/// — it just announced, so its gateway is up).
pub(super) fn rebuild_router(state: &CoordinatorState) {
    let weights: Vec<(String, f64)> = state
        .nodes
        .read()
        .unwrap()
        .values()
        .filter(|e| e.healthy)
        .filter(|e| e.status.as_ref().map(|s| s.live_replicas > 0).unwrap_or(true))
        .map(|e| {
            let w = e
                .status
                .as_ref()
                .map(|s| s.live_replicas.max(1) as f64)
                .unwrap_or(1.0);
            (e.announce.node_id.clone(), w)
        })
        .collect();
    state.router.write().unwrap().set_nodes(&weights);
}

/// A proxy attempt on one node failed at the transport layer: count it,
/// and after `node_timeout_beats` consecutive failures deroute the node
/// without waiting for the heartbeat sweep to notice.
fn note_node_error(state: &CoordinatorState, node_id: &str) {
    let mut died: Option<String> = None;
    {
        let mut nodes = state.nodes.write().unwrap();
        if let Some(e) = nodes.get_mut(node_id) {
            e.failures += 1;
            if e.healthy && e.failures >= state.cfg.node_timeout_beats {
                e.healthy = false;
                died = Some(e.announce.addr.clone());
            }
        }
    }
    if let Some(addr) = died {
        state.pool.purge(&addr);
        state.metrics.note_node_death();
        crate::warn!("cluster", "node {node_id} declared dead after repeated failures");
        rebuild_router(state);
    }
}

/// Feed one proxy-attempt outcome into the node's circuit breaker and
/// surface any state transition. Only real dispatch outcomes feed the
/// breaker — heartbeats poll a status endpoint and would mask a
/// slow-but-alive serving path with fast, healthy-looking samples.
fn note_breaker_outcome(state: &CoordinatorState, node_id: &str, ok: bool, latency: Duration) {
    let transition = {
        let mut nodes = state.nodes.write().unwrap();
        let Some(e) = nodes.get_mut(node_id) else {
            return;
        };
        e.breaker
            .record(ok, latency, Instant::now())
            .map(|t| (t, e.breaker.evidence()))
    };
    if let Some((t, evidence)) = transition {
        note_breaker_transition(state, node_id, t, &evidence);
    }
}

/// One breaker state change: metrics counter, flight-recorder entry, log
/// line. The node stays registered and heartbeated throughout — an open
/// breaker is a routing verdict, not a death certificate.
fn note_breaker_transition(
    state: &CoordinatorState,
    node_id: &str,
    t: BreakerTransition,
    evidence: &str,
) {
    state.metrics.note_breaker_transition(t.as_str());
    state.decisions.record(
        "coordinator",
        "breaker",
        t.as_str(),
        vec![
            ("node", node_id.to_string()),
            ("evidence", evidence.to_string()),
        ],
    );
    match t {
        BreakerTransition::Opened => {
            crate::warn!("cluster", "breaker opened for node {node_id}: {evidence}")
        }
        BreakerTransition::HalfOpened => {
            crate::info!("cluster", "breaker half-open for node {node_id}: probing")
        }
        BreakerTransition::Closed => {
            crate::info!("cluster", "breaker closed for node {node_id}: recovered ({evidence})")
        }
    }
}

fn accept_loop(listener: TcpListener, conn_tx: Sender<TcpStream>, state: &CoordinatorState) {
    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
                state.metrics.ingress.accepted_total.fetch_add(1, Ordering::Relaxed);
                state.metrics.ingress.open.fetch_add(1, Ordering::AcqRel);
                if conn_tx.send(stream).is_err() {
                    break;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn handle_connection(mut stream: TcpStream, state: &Arc<CoordinatorState>) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        let req = match http::read_request(&mut reader, state.cfg.max_body_bytes) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(e) => {
                let body =
                    openai::to_wire(&openai::error_body("invalid_request_error", &e.message));
                let _ = http::Response::json(e.status, body).write_to(&mut stream, false);
                break;
            }
        };
        let keep_alive = req.keep_alive();
        if route(&req, &mut stream, state).is_err() {
            break; // client went away mid-response
        }
        if !keep_alive {
            break;
        }
    }
}

/// Write the response and record request metrics.
fn finish(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &CoordinatorState,
    endpoint: &str,
    resp: http::Response,
) -> std::io::Result<()> {
    state.metrics.observe(endpoint, resp.status);
    resp.write_to(stream, req.keep_alive())
}

fn route(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions" | "/v1/chat/completions") => serve_proxy(req, stream, state),
        ("POST", "/cluster/join") => cluster_join(req, stream, state),
        // the versioned control API, served cluster-scoped by the
        // coordinator (nodes serve the same paths replica-scoped);
        // `GET /cluster/status` stays as a deprecated alias on a sunset
        // clock (counted, headered, gated by `--legacy-api`)
        ("GET", "/v1/admin/status") => admin_status(req, stream, state),
        ("GET", "/cluster/status") => legacy_alias(req, stream, state, "/cluster/status", || {
            http::Response::json(200, cluster_status_body(state).to_json().to_string_compact())
        }),
        ("POST", "/v1/admin/scale-up") => admin_scale_node(req, stream, state, true),
        ("POST", "/v1/admin/scale-down") => admin_scale_node(req, stream, state, false),
        ("POST", "/v1/admin/scale") => admin_scale_weights(req, stream, state),
        // snapshot/restore + live migration control surface
        ("POST", "/v1/admin/migrate") => admin_migrate(req, stream, state),
        ("GET", "/v1/admin/migrations") => {
            let resp = MigrationListResponse {
                service: "coordinator".into(),
                migrations: state.migrations.list(),
            };
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, "/v1/admin/migrations", http::Response::json(200, body))
        }
        ("GET", "/v1/admin/snapshots") => {
            let snapshots = state
                .snapshots
                .lock()
                .unwrap()
                .values()
                .map(|s| s.info.clone())
                .collect();
            let resp = SnapshotListResponse {
                service: "coordinator".into(),
                snapshots,
            };
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, "/v1/admin/snapshots", http::Response::json(200, body))
        }
        ("POST", "/v1/admin/snapshots") => admin_snapshot_capture(req, stream, state),
        ("GET", "/cluster/nodes") => {
            let rows: Vec<String> = node_samples(state)
                .iter()
                .map(|n| {
                    format!(
                        "{{\"node_id\":{},\"healthy\":{},\"ready\":{},\"live_replicas\":{}}}",
                        Json::Str(n.node_id.clone()).to_string_compact(),
                        n.healthy,
                        n.ready,
                        n.live_replicas
                    )
                })
                .collect();
            let body = format!("{{\"nodes\":[{}]}}", rows.join(","));
            finish(req, stream, state, "/cluster/nodes", http::Response::json(200, body))
        }
        ("GET", "/metrics") => {
            let nodes = node_samples(state);
            let sup = supervisor_snapshot(state);
            let body = render_prometheus(
                &state.metrics,
                &nodes,
                &sup,
                state.gate.inflight(),
                state.started.elapsed().as_secs_f64(),
            );
            finish(req, stream, state, "/metrics", http::Response::prometheus(body))
        }
        // versioned observability API: the typed envelope wraps the same
        // export the legacy aliases below still serve bare
        ("GET", "/v1/debug/traces") => {
            let resp =
                DebugExportResponse::new("traces", "coordinator", aggregated_traces(state));
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, "/v1/debug/traces", http::Response::json(200, body))
        }
        ("GET", "/v1/debug/decisions") => {
            let resp = DebugExportResponse::new(
                "decisions",
                "coordinator",
                state.decisions.export_json(),
            );
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, "/v1/debug/decisions", http::Response::json(200, body))
        }
        // fault injection runs on nodes, not on the routing layer: answer
        // a structured error pointing at the right target
        ("GET" | "POST", "/v1/admin/chaos") => {
            let err = AdminError::new(
                "unsupported",
                "fault injection is node-local; send /v1/admin/chaos to a node's gateway",
            )
            .with_detail("role", "coordinator");
            finish(
                req,
                stream,
                state,
                "/v1/admin/chaos",
                http::Response::json(400, err.to_json().to_string_compact()),
            )
        }
        ("GET", "/debug/traces") => legacy_alias(req, stream, state, "/debug/traces", || {
            http::Response::json(200, aggregated_traces(state).to_string_compact())
        }),
        ("GET", "/debug/decisions") => legacy_alias(req, stream, state, "/debug/decisions", || {
            http::Response::json(200, state.decisions.export_json().to_string_compact())
        }),
        ("GET", "/healthz") => {
            let nodes = state.nodes.read().unwrap().len();
            let body = format!(
                "{{\"status\":\"ok\",\"role\":\"coordinator\",\"uptime_seconds\":{:.3},\
                 \"nodes\":{nodes}}}",
                state.started.elapsed().as_secs_f64()
            );
            finish(req, stream, state, "/healthz", http::Response::json(200, body))
        }
        ("GET", "/ready") => {
            let serving = node_samples(state)
                .iter()
                .filter(|n| n.healthy && n.ready && n.live_replicas > 0)
                .count();
            let status = if serving > 0 { 200 } else { 503 };
            let body = format!("{{\"ready\":{},\"serving_nodes\":{serving}}}", serving > 0);
            finish(req, stream, state, "/ready", http::Response::json(status, body))
        }
        (_, "/v1/completions" | "/v1/chat/completions" | "/cluster/join" | "/cluster/nodes"
        | "/cluster/status" | "/v1/admin/status" | "/v1/admin/scale" | "/v1/admin/scale-up"
        | "/v1/admin/scale-down" | "/metrics" | "/healthz" | "/ready" | "/debug/traces"
        | "/debug/decisions" | "/v1/debug/traces" | "/v1/debug/decisions"
        | "/v1/admin/chaos" | "/v1/admin/migrate" | "/v1/admin/migrations"
        | "/v1/admin/snapshots") => {
            let body = openai::to_wire(&openai::error_body(
                "invalid_request_error",
                &format!("method {} not allowed on {}", req.method, req.path),
            ));
            finish(req, stream, state, "other", http::Response::json(405, body))
        }
        _ => {
            let body = openai::to_wire(&openai::error_body(
                "invalid_request_error",
                &format!("unknown path {}", req.path),
            ));
            finish(req, stream, state, "other", http::Response::json(404, body))
        }
    }
}

fn cluster_join(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    let bad = |msg: &str| {
        http::Response::json(
            400,
            openai::to_wire(&openai::error_body("invalid_request_error", msg)),
        )
    };
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return finish(req, stream, state, "/cluster/join", bad(&e.message)),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return finish(req, stream, state, "/cluster/join", bad(&format!("invalid JSON: {e}")))
        }
    };
    let announce = match NodeAnnounce::from_json(&json) {
        Ok(a) => a,
        Err(e) => return finish(req, stream, state, "/cluster/join", bad(&e)),
    };
    let (fresh, moved) = {
        let mut nodes = state.nodes.write().unwrap();
        let prior = nodes.get(&announce.node_id);
        let fresh = prior.is_none();
        let moved = prior.map(|e| e.announce.addr != announce.addr).unwrap_or(false);
        // a re-announce from the SAME address is bookkeeping, not health
        // evidence: an unhealthy node's outbound announces must not
        // override missed heartbeats — only a successful status poll (or a
        // restart at a new address) revives it. Status survives an
        // unchanged address; a node at a new address restarted, and its
        // old replica counts are history.
        // the breaker survives a same-address re-announce for the same
        // reason status does: degraded-node evidence is not erased by
        // bookkeeping. A restart at a new address starts closed.
        let (status, healthy, failures, breaker) = match prior {
            Some(e) if !moved => {
                (e.status.clone(), e.healthy, e.failures, e.breaker.clone())
            }
            _ => (None, true, 0, CircuitBreaker::new(state.cfg.breaker.clone())),
        };
        nodes.insert(
            announce.node_id.clone(),
            NodeEntry {
                announce: announce.clone(),
                status,
                healthy,
                failures,
                breaker,
            },
        );
        (fresh, moved)
    };
    if fresh || moved {
        crate::info!(
            "cluster",
            "node {} {} at {}",
            announce.node_id,
            if fresh { "joined" } else { "re-announced from a new address" },
            announce.addr
        );
        rebuild_router(state);
    }
    let nodes = state.nodes.read().unwrap().len();
    let body = format!("{{\"ok\":true,\"nodes\":{nodes}}}");
    finish(req, stream, state, "/cluster/join", http::Response::json(200, body))
}

/// The coordinator's cluster-scoped [`NodeStatus`]: the same wire shape a
/// node answers, aggregated over the healthy fleet — so one client can
/// poll `GET /v1/admin/status` against any role and parse one type.
fn cluster_status_body(state: &CoordinatorState) -> NodeStatus {
    let samples = node_samples(state);
    let mut status = NodeStatus {
        node_id: "coordinator".to_string(),
        live_replicas: 0,
        warm_replicas: 0,
        ready: false,
        gpu_memory_total: 0.0,
        gpu_memory_free: 0.0,
        frame: None,
        arrival_rps: 0.0,
        queue_wait: 0.0,
        batch_rps: 0.0,
    };
    let mut wait_weighted = 0.0f64;
    for n in samples.iter().filter(|n| n.healthy) {
        status.live_replicas += n.live_replicas;
        status.warm_replicas += n.warm_replicas;
        status.ready |= n.ready && n.live_replicas > 0;
        status.gpu_memory_total += n.gpu_memory_total;
        status.gpu_memory_free += n.gpu_memory_free;
        status.arrival_rps += n.arrival_rps;
        status.batch_rps += n.batch_rps;
        wait_weighted += n.queue_wait * n.live_replicas as f64;
    }
    if status.live_replicas > 0 {
        status.queue_wait = wait_weighted / status.live_replicas as f64;
    }
    status
}

/// `GET /v1/admin/status` (and the deprecated `/cluster/status` alias).
fn admin_status(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    let endpoint = req.path.clone();
    let body = cluster_status_body(state).to_json().to_string_compact();
    finish(req, stream, state, &endpoint, http::Response::json(200, body))
}

/// RFC 8594 sunset timestamp answered on every deprecated pre-v1 alias.
pub(super) const LEGACY_SUNSET: &str = "Thu, 31 Dec 2026 00:00:00 GMT";

/// Serve (or refuse) one deprecated pre-v1 alias: every hit counts into
/// `enova_api_deprecated_requests_total{path}` and carries `Deprecation` +
/// `Sunset` headers; with `--legacy-api off` the alias answers 410 Gone
/// with a structured error instead of the legacy body.
fn legacy_alias(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
    path: &str,
    build: impl FnOnce() -> http::Response,
) -> std::io::Result<()> {
    state.metrics.note_deprecated(path);
    let resp = if state.cfg.legacy_api {
        build()
    } else {
        let err = AdminError::new(
            "deprecated",
            "this pre-v1 path has been sunset; use the versioned /v1 API",
        )
        .with_detail("path", path);
        http::Response::json(410, err.to_json().to_string_compact())
    };
    finish(
        req,
        stream,
        state,
        path,
        resp.with_header("Deprecation", "true").with_header("Sunset", LEGACY_SUNSET),
    )
}

/// `POST /v1/admin/migrate`: run one live migration to completion and
/// answer its full [`MigrationStatus`] record — 200 when it lands, 409
/// with the failed record (structured error embedded) when it does not.
fn admin_migrate(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    let endpoint = "/v1/admin/migrate";
    let parsed = req
        .body_str()
        .map_err(|e| AdminError::new("invalid_request", &e.message))
        .and_then(|b| {
            Json::parse(b)
                .map_err(|e| AdminError::new("invalid_request", &format!("invalid JSON: {e}")))
        })
        .and_then(|j| MigrationRequest::from_json(&j));
    let mreq = match parsed {
        Ok(r) => r,
        Err(err) => {
            let body = err.to_json().to_string_compact();
            return finish(req, stream, state, endpoint, http::Response::json(400, body));
        }
    };
    let status = super::migrate::execute(state, &mreq, "migration");
    let http_status = if status.phase == MigrationPhase::Failed { 409 } else { 200 };
    let body = status.to_json().to_string_compact();
    finish(req, stream, state, endpoint, http::Response::json(http_status, body))
}

/// `POST /v1/admin/snapshots` at the coordinator: `capture` checkpoints a
/// node's engine (the named one, else the first ready node) and caches
/// the frame for backfill; `restore` is node-local and answers a
/// structured `unsupported` pointing at the right target.
fn admin_snapshot_capture(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    let endpoint = "/v1/admin/snapshots";
    let admin_err = |status: u16, err: AdminError| {
        http::Response::json(status, err.to_json().to_string_compact())
    };
    let parsed = req
        .body_str()
        .map_err(|e| AdminError::new("invalid_request", &e.message))
        .and_then(|b| {
            Json::parse(b)
                .map_err(|e| AdminError::new("invalid_request", &format!("invalid JSON: {e}")))
        })
        .and_then(|j| SnapshotRequest::from_json(&j));
    let sreq = match parsed {
        Ok(r) => r,
        Err(err) => return finish(req, stream, state, endpoint, admin_err(400, err)),
    };
    if sreq.action == SnapshotAction::Restore {
        let err = AdminError::new(
            "unsupported",
            "restore is node-local; POST the frame to a node's gateway, or use \
             /v1/admin/migrate to move a live replica",
        )
        .with_detail("role", "coordinator");
        return finish(req, stream, state, endpoint, admin_err(400, err));
    }
    let node_id = match &sreq.node {
        Some(n) => n.clone(),
        None => {
            let picked = node_samples(state)
                .into_iter()
                .find(|n| n.healthy && n.ready && n.live_replicas > 0)
                .map(|n| n.node_id);
            match picked {
                Some(id) => id,
                None => {
                    let err = AdminError::new(
                        "no_target",
                        "no ready node with a live replica to capture from",
                    );
                    return finish(req, stream, state, endpoint, admin_err(409, err));
                }
            }
        }
    };
    match super::migrate::capture_from_node(state, &node_id) {
        Ok(raw) => finish(req, stream, state, endpoint, http::Response::json(200, raw)),
        Err(err) => {
            let status = match err.code.as_str() {
                "unknown_node" => 404,
                "node_unhealthy" | "no_target" => 409,
                _ => 502,
            };
            finish(req, stream, state, endpoint, admin_err(status, err))
        }
    }
}

/// Backfill lost capacity from the newest stored engine snapshot: restore
/// onto the placement pick instead of cold-spawning, so a dead node's
/// replica is back in milliseconds. `Ok(None)` means no frame is stored
/// (the caller falls back to the cold path).
fn snapshot_backfill(state: &Arc<CoordinatorState>) -> Result<Option<PlacementEvent>> {
    let stored = {
        let snaps = state.snapshots.lock().unwrap();
        snaps
            .iter()
            .max_by(|a, b| a.1.info.taken_unix.total_cmp(&b.1.info.taken_unix))
            .map(|(node, s)| (node.clone(), s.info.clone(), s.hex.clone()))
    };
    let Some((snap_source, info, hex)) = stored else {
        return Ok(None);
    };
    let invs = inventories(state);
    let chosen = placement::place_replica(&invs)
        .ok_or_else(|| anyhow!("no node has room for the restored replica"))?
        .node_id
        .clone();
    let addr = state
        .nodes
        .read()
        .unwrap()
        .get(&chosen)
        .map(|e| e.announce.addr.clone())
        .ok_or_else(|| anyhow!("node {chosen} vanished mid-restore"))?;
    let body = SnapshotRequest::restore(&hex).to_json().to_string_compact();
    let t0 = Instant::now();
    let (status, raw) = super::migrate::pool_rpc(
        &state.pool,
        &addr,
        "POST",
        "/v1/admin/snapshots",
        Some(&body),
        SCALE_RPC_TIMEOUT,
    )?;
    if !(200..300).contains(&status) {
        bail!("node {chosen} refused the snapshot restore with {status}: {raw}");
    }
    let replica_id = Json::parse(&raw)
        .ok()
        .and_then(|j| j.get("replica_id").and_then(Json::as_usize))
        .unwrap_or(0) as u64;
    let restore_seconds = t0.elapsed().as_secs_f64();
    {
        let mut nodes = state.nodes.write().unwrap();
        if let Some(e) = nodes.get_mut(&chosen) {
            if let Some(s) = e.status.as_mut() {
                s.live_replicas += 1;
                s.gpu_memory_free =
                    (s.gpu_memory_free - e.announce.replica_gpu_memory).max(0.0);
            }
        }
    }
    rebuild_router(state);
    state.metrics.note_placement("backfill");
    let event = PlacementEvent {
        at: state.started.elapsed().as_secs_f64(),
        node_id: chosen.clone(),
        replica_id,
        reason: "backfill",
        up: true,
    };
    {
        let mut sup = state.supervisor.lock().unwrap();
        sup.scale_ups += 1;
        sup.events.push(event.clone());
    }
    state.decisions.record(
        "coordinator",
        "placement",
        "backfill",
        vec![
            ("node", chosen.clone()),
            ("replica_id", replica_id.to_string()),
            ("mode", "snapshot".to_string()),
            ("bin_packing", inventory_summary(&invs)),
        ],
    );
    // the migration view of the same act: the lost node's capacity moved
    // to a survivor by snapshot transfer rather than cold re-init
    state.decisions.record(
        "coordinator",
        "migration",
        "backfill",
        vec![
            ("source", snap_source.clone()),
            ("target", chosen.clone()),
            ("new_replica_id", replica_id.to_string()),
            ("engine_kind", info.engine_kind.clone()),
            ("restore_seconds", format!("{restore_seconds:.4}")),
        ],
    );
    state.migrations.put(MigrationStatus {
        id: state.migrations.allocate(),
        source_node: snap_source.clone(),
        target_node: chosen.clone(),
        reason: "backfill".into(),
        phase: MigrationPhase::Done,
        new_replica_id: Some(replica_id),
        error: None,
        started_unix: super::migrate::unix_now(),
        snapshot_seconds: 0.0,
        restore_seconds,
        retire_seconds: 0.0,
        total_seconds: restore_seconds,
    });
    crate::info!(
        "cluster",
        "backfilled a replica on node {chosen} from node {snap_source}'s snapshot \
         in {:.1}ms",
        restore_seconds * 1e3
    );
    Ok(Some(event))
}

/// `POST /v1/admin/scale-{up,down}` at the cluster level: one placement
/// (or drain) through the same path the supervisor uses, with reason
/// `admin`. The supervisor's target follows the manual change so backfill
/// does not immediately undo an admin drain.
fn admin_scale_node(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
    up: bool,
) -> std::io::Result<()> {
    let endpoint = req.path.clone();
    let admin_err = |status: u16, err: AdminError| {
        http::Response::json(status, err.to_json().to_string_compact())
    };
    let live: usize = node_samples(state)
        .iter()
        .filter(|n| n.healthy)
        .map(|n| n.live_replicas)
        .sum();
    let policy = &state.cfg.policy;
    if up && live >= policy.max_replicas {
        let err = AdminError::new("cluster_full", "cluster is at its replica ceiling")
            .with_detail("live_replicas", &live.to_string())
            .with_detail("max_replicas", &policy.max_replicas.to_string());
        return finish(req, stream, state, &endpoint, admin_err(409, err));
    }
    if !up && live <= policy.min_replicas {
        let err = AdminError::new("cluster_at_floor", "cluster is at its replica floor")
            .with_detail("live_replicas", &live.to_string())
            .with_detail("min_replicas", &policy.min_replicas.to_string());
        return finish(req, stream, state, &endpoint, admin_err(409, err));
    }
    let result = if up {
        scale_up(state, "admin")
    } else {
        scale_down(state, "admin")
    };
    match result {
        Ok(event) => {
            let live_now = if up { live + 1 } else { live.saturating_sub(1) };
            state.target_replicas.store(
                live_now.clamp(policy.min_replicas, policy.max_replicas),
                Ordering::Release,
            );
            let body = AdminNodeScaleResponse {
                node_id: event.node_id,
                direction: if up {
                    AdminScaleDirection::Up
                } else {
                    AdminScaleDirection::Down
                },
                replica_id: event.replica_id,
                live_replicas: live_now,
            }
            .to_json()
            .to_string_compact();
            finish(req, stream, state, &endpoint, http::Response::json(200, body))
        }
        Err(e) => {
            let code = if up { "placement_failed" } else { "drain_failed" };
            finish(
                req,
                stream,
                state,
                &endpoint,
                admin_err(409, AdminError::new(code, &format!("{e:#}"))),
            )
        }
    }
}

/// `POST /v1/admin/scale` — replica router weights are a per-process
/// concern; the coordinator routes *nodes*, so it answers a structured
/// error pointing at the right target instead of a bare 404.
fn admin_scale_weights(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    let err = AdminError::new(
        "unsupported",
        "replica weights are per-process; POST /v1/admin/scale to a node's gateway",
    )
    .with_detail("role", "coordinator");
    finish(
        req,
        stream,
        state,
        "/v1/admin/scale",
        http::Response::json(400, err.to_json().to_string_compact()),
    )
}

/// Per-node batch-traffic share from the latest heartbeat statuses, and
/// the SLO tier the next placement should serve: batch when the fleet's
/// mixture is batch-dominated (consolidate throughput traffic), latency
/// otherwise (new capacity lands away from batch-heavy nodes, where the
/// interactive tenants route).
fn placement_context(state: &CoordinatorState) -> (BTreeMap<String, f64>, SloTier) {
    let nodes = state.nodes.read().unwrap();
    let mut shares = BTreeMap::new();
    let (mut total, mut batch) = (0.0f64, 0.0f64);
    for e in nodes.values().filter(|e| e.healthy) {
        let Some(s) = e.status.as_ref() else { continue };
        total += s.arrival_rps;
        batch += s.batch_rps;
        if s.arrival_rps > 1e-9 {
            shares.insert(
                e.announce.node_id.clone(),
                (s.batch_rps / s.arrival_rps).clamp(0.0, 1.0),
            );
        }
    }
    let tier = if total > 1e-9 && batch / total > placement::BATCH_HEAVY_SHARE {
        SloTier::Batch
    } else {
        SloTier::Latency
    };
    (shares, tier)
}

/// What one proxy attempt produced.
enum Attempt {
    /// a response (any status) was fully delivered to the client
    Done(u16),
    /// writing to the *client* failed — abort the connection
    ClientGone(std::io::Error),
    /// the node failed before anything was committed to the client:
    /// transport error, or a retryable shed/overload status
    Retry { transport: bool, status: Option<u16> },
}

/// Statuses that are safe and useful to re-dispatch: the node refused or
/// could not serve (shed, shutting down, overloaded, engine failure) and
/// no completion was produced, so another node can take the request.
fn retryable_status(status: u16) -> bool {
    matches!(status, 429 | 500 | 502 | 503 | 504)
}

fn serve_proxy(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<CoordinatorState>,
) -> std::io::Result<()> {
    let endpoint = req.path.clone();
    let bad = |msg: &str| {
        http::Response::json(
            400,
            openai::to_wire(&openai::error_body("invalid_request_error", msg)),
        )
    };
    let body = match req.body_str() {
        Ok(b) => b.to_string(),
        Err(e) => return finish(req, stream, state, &endpoint, bad(&e.message)),
    };
    let json = match Json::parse(&body) {
        Ok(j) => j,
        Err(e) => return finish(req, stream, state, &endpoint, bad(&format!("invalid JSON: {e}"))),
    };
    let stream_mode = json.get("stream").and_then(Json::as_bool).unwrap_or(false);

    // tenant identity, resolved exactly the way a node's gateway resolves
    // it (header > API key > body `user` hint). The coordinator uses only
    // the SLO tier — to steer latency traffic away from batch-heavy nodes
    // — while per-tenant admission and the cost ledger stay node-side,
    // fed by the forwarded identity headers below.
    let tenant = state.tenants.resolve(
        req.header("x-enova-tenant"),
        req.header("authorization")
            .map(str::trim)
            .map(|v| v.strip_prefix("Bearer ").unwrap_or(v)),
        json.get("user").and_then(Json::as_str),
    );
    let mut forward_head = String::new();
    if let Some(v) = req.header("x-enova-tenant") {
        forward_head.push_str(&format!("x-enova-tenant: {v}\r\n"));
    }
    if let Some(v) = req.header("authorization") {
        forward_head.push_str(&format!("Authorization: {v}\r\n"));
    }
    // latency-tier steering: prefer nodes whose traffic is not
    // batch-dominated. A preference, never a filter — when only
    // batch-heavy nodes have capacity they still serve the request.
    let prefer: Vec<String> = if tenant.tier() == SloTier::Latency {
        state
            .nodes
            .read()
            .unwrap()
            .values()
            .filter(|e| e.healthy)
            .filter(|e| {
                e.status
                    .as_ref()
                    .map(|s| {
                        s.arrival_rps <= 1e-9
                            || s.batch_rps / s.arrival_rps <= placement::BATCH_HEAVY_SHARE
                    })
                    .unwrap_or(true)
            })
            .map(|e| e.announce.node_id.clone())
            .collect()
    } else {
        Vec::new()
    };

    // trace context: adopt an inbound `traceparent` (the coordinator is
    // usually the mint point, but a fronting proxy may own the ID) or
    // mint one; the sampling decision made here rides the flags bit to
    // every node this request touches.
    let ctx = req
        .header("traceparent")
        .and_then(TraceContext::parse)
        .map(|c| c.child())
        .unwrap_or_else(|| TraceContext::mint(state.cfg.trace.sample_rate));
    let trace = ActiveTrace::begin(ctx, "coordinator", &endpoint);

    // admission control at the ingress owner: rate, then bounded in-flight
    if let Some(bucket) = &state.bucket {
        if !bucket.lock().unwrap().try_take() {
            state.metrics.note_rate_limited();
            trace.phase(PHASE_ADMISSION, trace.started(), Instant::now());
            record_trace(state, &trace, 429);
            let resp = http::Response::json(
                429,
                openai::to_wire(&openai::error_body(
                    "rate_limit_exceeded",
                    "request rate over the configured limit; retry later",
                )),
            )
            .with_header("Retry-After", "1");
            return finish(req, stream, state, &endpoint, resp);
        }
    }
    let Some(_permit) = AdmissionGate::try_acquire(&state.gate) else {
        state.metrics.note_queue_full();
        trace.phase(PHASE_ADMISSION, trace.started(), Instant::now());
        record_trace(state, &trace, 429);
        let resp = http::Response::json(
            429,
            openai::to_wire(&openai::error_body(
                "server_overloaded",
                &format!(
                    "admission queue full ({} in flight); retry later",
                    state.gate.capacity()
                ),
            )),
        )
        .with_header("Retry-After", "1");
        return finish(req, stream, state, &endpoint, resp);
    };
    trace.phase(PHASE_ADMISSION, trace.started(), Instant::now());

    let mut excluded: Vec<String> = Vec::new();
    let mut last_failure = String::from("no serving nodes registered");
    // circuit breakers: open (cooling-down) nodes and half-open nodes
    // whose probe budget is spent are excluded from dispatch up front — a
    // degraded node keeps its replicas and heartbeats, it just stops
    // receiving traffic until probes prove it recovered. The read-only
    // check never consumes probe budget (see `CircuitBreaker::would_block`).
    {
        let now = Instant::now();
        let nodes = state.nodes.read().unwrap();
        excluded.extend(
            nodes
                .values()
                .filter(|e| e.breaker.would_block(now))
                .map(|e| e.announce.node_id.clone()),
        );
    }
    for attempt in 0..state.cfg.dispatch_attempts.max(1) {
        // lock-free dispatch: hold the router lock only for the O(1)
        // snapshot clone, then scan without serializing against
        // heartbeat-driven rebuilds
        let routable = state.router.read().unwrap().snapshot();
        let picked = if !prefer.is_empty() {
            routable.dispatch_preferring(&prefer, &excluded)
        } else if excluded.is_empty() {
            routable.dispatch()
        } else {
            routable.dispatch_excluding(&excluded)
        };
        let Some((node_id, handle)) = picked else {
            break;
        };
        let addr = state
            .nodes
            .read()
            .unwrap()
            .get(&node_id)
            .map(|e| e.announce.addr.clone());
        let Some(addr) = addr else {
            handle.complete();
            excluded.push(node_id);
            continue;
        };
        // breaker gate on the actual pick: flips open → half-open once
        // the cooldown elapsed and spends one probe admission while
        // half-open — probe budget is only ever consumed here, for a
        // request that really dispatches to the node
        let gate = {
            let mut nodes = state.nodes.write().unwrap();
            nodes.get_mut(&node_id).map(|e| {
                let (allowed, t) = e.breaker.allow(Instant::now());
                (allowed, t.map(|t| (t, e.breaker.evidence())))
            })
        };
        if let Some((_, Some((t, ev)))) = &gate {
            note_breaker_transition(state, &node_id, *t, ev);
        }
        if !matches!(gate, Some((true, _))) {
            handle.complete();
            last_failure = format!("node {node_id} breaker open");
            excluded.push(node_id);
            continue;
        }
        if attempt > 0 {
            state.metrics.note_proxy_retry();
        }
        // each attempt is a child span so node-side spans parent onto the
        // attempt that actually carried them
        let attempt_ctx = trace.ctx().child();
        let attempt_start = Instant::now();
        let outcome = proxy_attempt(
            state,
            &addr,
            &endpoint,
            &body,
            stream_mode,
            &attempt_ctx.to_traceparent(),
            &forward_head,
            stream,
        );
        handle.complete();
        let attempt_end = Instant::now();
        trace.span(
            "proxy",
            SpanKind::Proxy,
            attempt_start,
            attempt_end,
            vec![("node", node_id.clone()), ("attempt", attempt.to_string())],
        );
        let attempt_latency = attempt_end.saturating_duration_since(attempt_start);
        match outcome {
            Attempt::Done(status) => {
                note_breaker_outcome(state, &node_id, status < 500, attempt_latency);
                record_trace(state, &trace, status);
                state.metrics.observe(&endpoint, status);
                return Ok(());
            }
            Attempt::ClientGone(e) => {
                // the client went away; no verdict on the node's health
                record_trace(state, &trace, 499);
                state.metrics.observe(&endpoint, 499);
                return Err(e);
            }
            Attempt::Retry { transport, status } => {
                note_breaker_outcome(state, &node_id, false, attempt_latency);
                last_failure = match status {
                    Some(code) => format!("node {node_id} answered {code}"),
                    None => format!("node {node_id} transport failure"),
                };
                let cause = match status {
                    Some(code) if !transport => format!("shed_{code}"),
                    _ => "node_death".to_string(),
                };
                trace.span(
                    "retry",
                    SpanKind::Retry,
                    attempt_start,
                    attempt_end,
                    vec![("cause", cause), ("node", node_id.clone())],
                );
                if transport {
                    note_node_error(state, &node_id);
                }
                excluded.push(node_id);
            }
        }
    }
    record_trace(state, &trace, 503);
    let resp = http::Response::json(
        503,
        openai::to_wire(&openai::error_body(
            "service_unavailable",
            &format!("no node could serve the request: {last_failure}"),
        )),
    )
    .with_header("Retry-After", "1");
    finish(req, stream, state, &endpoint, resp)
}

/// Finish the request's trace and hand it to the tail-retention ring.
fn record_trace(state: &CoordinatorState, trace: &ActiveTrace, status: u16) {
    state.tracer.record(trace.finish(status, state.cfg.trace.slo));
}

/// The cluster `/debug/traces` body: the coordinator's own records, with
/// every healthy node's `/debug/traces` fetched and its spans merged into
/// the coordinator record of the same trace ID — one trace, both sides.
/// Node records whose coordinator side was dropped (sampling, ring
/// eviction) surface under `node_only_traces` rather than vanishing.
fn aggregated_traces(state: &CoordinatorState) -> Json {
    let targets: Vec<String> = state
        .nodes
        .read()
        .unwrap()
        .values()
        .filter(|e| e.healthy)
        .map(|e| e.announce.addr.clone())
        .collect();
    let mut nodes_polled = 0usize;
    let mut remote: BTreeMap<String, Vec<Json>> = BTreeMap::new();
    for addr in &targets {
        let Some(json) =
            loadgen::request(addr, "GET", "/debug/traces", None, HEARTBEAT_RPC_TIMEOUT)
                .ok()
                .filter(|r| r.status == 200)
                .and_then(|r| r.json().ok())
        else {
            continue;
        };
        nodes_polled += 1;
        if let Some(traces) = json.get("traces").and_then(Json::as_arr) {
            for t in traces {
                let Some(id) = t.get("trace_id").and_then(Json::as_str) else {
                    continue;
                };
                remote.entry(id.to_string()).or_default().push(t.clone());
            }
        }
    }
    let mut export = state.tracer.export_json();
    if let Json::Obj(map) = &mut export {
        if let Some(Json::Arr(traces)) = map.get_mut("traces") {
            for t in traces.iter_mut() {
                let Json::Obj(rec) = t else { continue };
                let Some(id) = rec.get("trace_id").and_then(Json::as_str).map(str::to_string)
                else {
                    continue;
                };
                let Some(node_recs) = remote.remove(&id) else {
                    continue;
                };
                if let Some(Json::Arr(spans)) = rec.get_mut("spans") {
                    for r in &node_recs {
                        if let Some(rs) = r.get("spans").and_then(Json::as_arr) {
                            spans.extend(rs.iter().cloned());
                        }
                    }
                }
            }
        }
        map.insert("nodes_polled".to_string(), Json::Num(nodes_polled as f64));
        map.insert(
            "node_only_traces".to_string(),
            Json::Arr(remote.into_values().flatten().collect()),
        );
    }
    export
}

/// The per-attempt proxy parameters that travel together.
struct ProxyHop<'a> {
    addr: &'a str,
    path: &'a str,
    body: &'a str,
    stream_mode: bool,
    traceparent: &'a str,
    /// pre-rendered `header: value\r\n` lines forwarded verbatim (tenant
    /// identity: `x-enova-tenant`, `Authorization`); empty when the client
    /// sent neither
    extra_head: &'a str,
}

/// Run one exchange against `addr`, relaying the outcome to the client
/// per the atomicity rules: unary responses are buffered (so nothing
/// reaches the client unless the node answered), SSE streams are relayed
/// frame-by-frame and only become non-retryable once the first frame has
/// been forwarded.
///
/// Connections come from the keep-alive [`NodePool`] when one is parked.
/// A transport failure on a *reused* socket before anything was committed
/// to the client redials once on a fresh connection — the node may simply
/// have reaped the idle socket — so pooling never turns an ordinary idle
/// sweep into node blame (`note_node_error`) or a burned dispatch attempt.
fn proxy_attempt(
    state: &CoordinatorState,
    addr: &str,
    path: &str,
    body: &str,
    stream_mode: bool,
    traceparent: &str,
    extra_head: &str,
    client: &mut TcpStream,
) -> Attempt {
    let hop = ProxyHop {
        addr,
        path,
        body,
        stream_mode,
        traceparent,
        extra_head,
    };
    let mut force_fresh = false;
    loop {
        let pooled = if force_fresh {
            None
        } else {
            state.pool.checkout(addr)
        };
        let reused = pooled.is_some();
        let upstream = match pooled {
            Some(s) => {
                state.metrics.note_upstream_reuse();
                s
            }
            None => {
                state.metrics.note_upstream_dial();
                match open_upstream(addr, state.cfg.request_timeout) {
                    Ok(s) => s,
                    Err(_) => return Attempt::Retry { transport: true, status: None },
                }
            }
        };
        state.metrics.set_upstream_pool_idle(state.pool.idle_count());
        match proxy_once(state, upstream, &hop, client) {
            Attempt::Retry {
                transport: true,
                status: None,
            } if reused => force_fresh = true,
            outcome => return outcome,
        }
    }
}

/// One request/response exchange on an already-open node connection.
/// Parks the connection back in the pool when the response ended at a
/// clean framing boundary and the node did not ask to close.
fn proxy_once(
    state: &CoordinatorState,
    upstream: TcpStream,
    hop: &ProxyHop<'_>,
    client: &mut TcpStream,
) -> Attempt {
    // pooled sockets keep whatever timeouts they had; re-arm per attempt
    let _ = upstream.set_read_timeout(Some(state.cfg.request_timeout));
    let _ = upstream.set_write_timeout(Some(state.cfg.request_timeout));
    {
        let mut w = &upstream;
        // keep-alive head (no `Connection: close`): the node parks the
        // connection after answering and the pool reuses it
        let head = format!(
            "POST {} HTTP/1.1\r\nHost: {}\r\nAccept: */*\r\n\
             traceparent: {}\r\n{}\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n",
            hop.path,
            hop.addr,
            hop.traceparent,
            hop.extra_head,
            hop.body.len()
        );
        if w.write_all(head.as_bytes())
            .and_then(|_| w.write_all(hop.body.as_bytes()))
            .and_then(|_| w.flush())
            .is_err()
        {
            return Attempt::Retry { transport: true, status: None };
        }
    }
    let mut reader = BufReader::new(upstream);
    let (status, headers) = match read_response_head(&mut reader) {
        Ok(h) => h,
        Err(_) => return Attempt::Retry { transport: true, status: None },
    };
    let upstream_keep_alive = !headers
        .get("connection")
        .map(|v| v.eq_ignore_ascii_case("close"))
        .unwrap_or(false);

    let is_sse = headers
        .get("content-type")
        .map(|v| v.starts_with("text/event-stream"))
        .unwrap_or(false);
    if hop.stream_mode && status == 200 && is_sse {
        let (outcome, clean) = relay_sse(state, &mut reader, client);
        if clean && upstream_keep_alive {
            checkin_upstream(state, hop.addr, reader);
        }
        return outcome;
    }

    // unary (or error) path: buffer the whole upstream body first, so a
    // node that dies mid-response never half-commits the client
    let framed =
        headers.contains_key("transfer-encoding") || headers.contains_key("content-length");
    let upstream_body = match read_framed_body(&mut reader, &headers) {
        Ok(b) => b,
        Err(_) => return Attempt::Retry { transport: true, status: None },
    };
    // a framed body ends at a known boundary, so the socket is reusable
    // even when the node answered a retryable shed status
    if framed && upstream_keep_alive {
        checkin_upstream(state, hop.addr, reader);
    }
    if retryable_status(status) {
        return Attempt::Retry { transport: false, status: Some(status) };
    }
    let resp = http::Response::json(status, String::from_utf8_lossy(&upstream_body).into_owned());
    // the client asked for keep-alive handling at the outer layer; the
    // proxy always answers framed bodies, so keep-alive is safe
    match resp.write_to(client, true) {
        Ok(()) => Attempt::Done(status),
        Err(e) => Attempt::ClientGone(e),
    }
}

/// Park an upstream connection whose response was fully consumed. A
/// non-empty read-ahead buffer means unconsumed response bytes would be
/// lost with the `BufReader` — those sockets are dropped instead.
fn checkin_upstream(state: &CoordinatorState, addr: &str, reader: BufReader<TcpStream>) {
    if reader.buffer().is_empty() {
        state.pool.checkin(addr, reader.into_inner());
        state.metrics.set_upstream_pool_idle(state.pool.idle_count());
    }
}

/// Relay an SSE stream zero-copy: upstream chunk frames are forwarded to
/// the client *verbatim* at frame boundaries (no decode, no re-framing —
/// the terminal `0\r\n\r\n` ends the client's response exactly where the
/// node's ended), with a [`ChunkFrameScanner`] tracking boundaries. The
/// client's SSE head is written lazily on the first complete frame: until
/// then an upstream death simply re-dispatches. After it, an upstream
/// death terminates the stream with a `service_unavailable` event and a
/// clean chunked close — the same shape a single-node gateway gives a
/// mid-stream engine failure (the client only ever saw whole frames, so
/// the injected event lands on a valid boundary).
///
/// The second return value is true when the stream ended at a clean
/// response boundary (the connection is poolable).
fn relay_sse<R: BufRead>(
    state: &CoordinatorState,
    upstream: &mut R,
    client: &mut TcpStream,
) -> (Attempt, bool) {
    enum Step {
        Forwarded { consumed: usize, terminal: bool },
        UpstreamGone,
    }
    let mut scanner = ChunkFrameScanner::new();
    let mut started = false;
    let mut relayed = 0usize;
    loop {
        let step = match upstream.fill_buf() {
            Ok(buf) if buf.is_empty() => Step::UpstreamGone,
            Err(_) => Step::UpstreamGone,
            Ok(buf) => {
                let n = buf.len();
                match scanner.push(buf) {
                    // malformed chunk framing is handled like a death:
                    // terminate (or retry, pre-commit) rather than
                    // forward bytes we cannot bound
                    Err(_) => Step::UpstreamGone,
                    Ok(scan) => {
                        if !scan.carry_flush.is_empty() || !scan.emit.is_empty() {
                            if !started {
                                if let Err(e) = write_sse_head(client) {
                                    return (Attempt::ClientGone(e), false);
                                }
                                started = true;
                            }
                            if let Err(e) = client
                                .write_all(&scan.carry_flush)
                                .and_then(|_| client.write_all(scan.emit))
                                .and_then(|_| client.flush())
                            {
                                return (Attempt::ClientGone(e), false);
                            }
                        }
                        relayed += scan.data_frames;
                        Step::Forwarded {
                            consumed: n,
                            terminal: scan.terminal,
                        }
                    }
                }
            }
        };
        match step {
            Step::Forwarded { consumed, terminal } => {
                upstream.consume(consumed);
                if terminal {
                    state.metrics.add_sse_chunks(relayed);
                    // the terminal frame passed through verbatim, so the
                    // client's chunked response is already complete
                    return (Attempt::Done(200), scanner.is_clean());
                }
            }
            Step::UpstreamGone => {
                if !started {
                    // nothing committed to the client yet: safe to retry
                    return (Attempt::Retry { transport: true, status: None }, false);
                }
                state.metrics.add_sse_chunks(relayed);
                let event = format!(
                    "data: {}\n\n",
                    openai::to_wire(&openai::error_body(
                        "service_unavailable",
                        "serving node went away mid-stream",
                    ))
                );
                let framed = format!("{:x}\r\n{event}\r\n0\r\n\r\n", event.len());
                return match client
                    .write_all(framed.as_bytes())
                    .and_then(|_| client.flush())
                {
                    Ok(()) => (Attempt::Done(200), false),
                    Err(e) => (Attempt::ClientGone(e), false),
                };
            }
        }
    }
}

fn open_upstream(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let stream = match addr.parse::<SocketAddr>() {
        Ok(sa) => TcpStream::connect_timeout(&sa, Duration::from_secs(2))
            .with_context(|| format!("connect {addr}"))?,
        Err(_) => TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?,
    };
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

fn read_framed_body<R: BufRead>(
    r: &mut R,
    headers: &BTreeMap<String, String>,
) -> Result<Vec<u8>> {
    if headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false)
    {
        let mut body = Vec::new();
        while let Some(chunk) = read_chunk(r)? {
            body.extend_from_slice(&chunk);
            if body.len() > MAX_PROXY_BODY {
                bail!("upstream body over the proxy limit");
            }
        }
        return Ok(body);
    }
    if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse().context("bad upstream Content-Length")?;
        if len > MAX_PROXY_BODY {
            bail!("upstream body of {len} bytes over the proxy limit");
        }
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf)?;
        return Ok(buf);
    }
    let mut buf = Vec::new();
    r.take(MAX_PROXY_BODY as u64 + 1).read_to_end(&mut buf)?;
    if buf.len() > MAX_PROXY_BODY {
        bail!("unframed upstream body over the proxy limit");
    }
    Ok(buf)
}

/// Poll every registered node's `/v1/admin/status`, flip health on
/// consecutive misses, and rebuild the router each sweep.
fn heartbeat_loop(state: &Arc<CoordinatorState>) {
    loop {
        if sleep_interruptible(state, state.cfg.heartbeat_interval) {
            break;
        }
        let targets: Vec<(String, String)> = state
            .nodes
            .read()
            .unwrap()
            .values()
            .map(|e| (e.announce.node_id.clone(), e.announce.addr.clone()))
            .collect();
        // poll concurrently: one wedged node (2s RPC timeout) must not
        // stretch the sweep for the whole fleet and delay dead-node
        // deroute of the others
        let polls: Vec<(String, Option<NodeStatus>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = targets
                .into_iter()
                .map(|(node_id, addr)| {
                    scope.spawn(move || {
                        let polled = loadgen::request(
                            &addr,
                            "GET",
                            "/v1/admin/status",
                            None,
                            HEARTBEAT_RPC_TIMEOUT,
                        )
                        .ok()
                        .filter(|resp| resp.status == 200)
                        .and_then(|resp| resp.json().ok())
                        .and_then(|j| NodeStatus::from_json(&j).ok());
                        (node_id, polled)
                    })
                })
                .collect();
            handles.into_iter().filter_map(|h| h.join().ok()).collect()
        });
        for (node_id, polled) in polls {
            let mut died = false;
            let mut revived = false;
            {
                let mut nodes = state.nodes.write().unwrap();
                let Some(entry) = nodes.get_mut(&node_id) else {
                    continue;
                };
                match polled {
                    Some(status) if status.node_id == node_id => {
                        revived = !entry.healthy;
                        entry.status = Some(status);
                        entry.healthy = true;
                        entry.failures = 0;
                    }
                    _ => {
                        entry.failures += 1;
                        if entry.healthy && entry.failures >= state.cfg.node_timeout_beats {
                            entry.healthy = false;
                            died = true;
                            state.pool.purge(&entry.announce.addr);
                        }
                    }
                }
            }
            if died {
                state.metrics.note_node_death();
                crate::warn!(
                    "cluster",
                    "node {node_id} missed {} heartbeats; derouted",
                    state.cfg.node_timeout_beats
                );
            }
            if revived {
                crate::info!("cluster", "node {node_id} back from the dead; rerouting");
            }
        }
        rebuild_router(state);
    }
}

/// Healthy-node inventories for the placement math.
pub(super) fn inventories(state: &CoordinatorState) -> Vec<NodeInventory> {
    state
        .nodes
        .read()
        .unwrap()
        .values()
        .filter(|e| e.healthy)
        .filter_map(|e| {
            let status = e.status.as_ref()?;
            Some(NodeInventory {
                node_id: e.announce.node_id.clone(),
                gpu_memory_total: e.announce.gpu_memory_total,
                gpu_memory_free: status.gpu_memory_free,
                replica_gpu_memory: e.announce.replica_gpu_memory,
                live_replicas: status.live_replicas,
                max_replicas: e.announce.max_replicas,
            })
        })
        .collect()
}

/// Execute one scale-up placement: choose the node, ask it, and account
/// optimistically so a second placement in the same heartbeat window sees
/// the updated fill.
pub(super) fn scale_up(state: &Arc<CoordinatorState>, reason: &'static str) -> Result<PlacementEvent> {
    let invs = inventories(state);
    // tier-aware bin packing: the demand tier and per-node batch shares
    // come from the latest heartbeat statuses, so latency-driven growth
    // lands away from batch-heavy nodes (and batch-driven growth
    // consolidates onto them)
    let (batch_share, tier) = placement_context(state);
    let chosen = placement::place_replica_tiered(&invs, &batch_share, tier)
        .ok_or_else(|| anyhow!("no node has room for another replica"))?
        .node_id
        .clone();
    let addr = state
        .nodes
        .read()
        .unwrap()
        .get(&chosen)
        .map(|e| e.announce.addr.clone())
        .ok_or_else(|| anyhow!("node {chosen} vanished mid-placement"))?;
    let resp = loadgen::request(&addr, "POST", "/v1/admin/scale-up", Some("{}"), SCALE_RPC_TIMEOUT)
        .with_context(|| format!("scale-up RPC to {chosen}"))?;
    if !(200..300).contains(&resp.status) {
        bail!("node {chosen} refused scale-up with {}: {}", resp.status, resp.body_str());
    }
    let replica_id = resp
        .json()
        .ok()
        .and_then(|j| j.get("replica_id").and_then(Json::as_usize))
        .unwrap_or(0) as u64;
    {
        let mut nodes = state.nodes.write().unwrap();
        if let Some(e) = nodes.get_mut(&chosen) {
            if let Some(s) = e.status.as_mut() {
                s.live_replicas += 1;
                s.gpu_memory_free =
                    (s.gpu_memory_free - e.announce.replica_gpu_memory).max(0.0);
            }
        }
    }
    rebuild_router(state);
    state.metrics.note_placement(reason);
    let event = PlacementEvent {
        at: state.started.elapsed().as_secs_f64(),
        node_id: chosen.clone(),
        replica_id,
        reason,
        up: true,
    };
    crate::info!(
        "cluster",
        "placed replica {replica_id} on node {chosen} (reason: {reason})"
    );
    let (forecast_rps, forecast_wmape) = {
        let mut sup = state.supervisor.lock().unwrap();
        sup.scale_ups += 1;
        sup.events.push(event.clone());
        (sup.last_forecast, sup.forecast_error)
    };
    state.decisions.record(
        "coordinator",
        "placement",
        reason,
        vec![
            ("node", chosen.clone()),
            ("replica_id", replica_id.to_string()),
            ("bin_packing", inventory_summary(&invs)),
            ("forecast_rps", format!("{forecast_rps:.3}")),
            ("forecast_wmape", format!("{forecast_wmape:.4}")),
        ],
    );
    Ok(event)
}

/// One-line bin-packing input snapshot: what every candidate node looked
/// like when the placement chose among them.
fn inventory_summary(invs: &[NodeInventory]) -> String {
    invs.iter()
        .map(|i| {
            format!(
                "{}={:.1}/{:.1}GB,{}r/{}max",
                i.node_id, i.gpu_memory_free, i.gpu_memory_total, i.live_replicas, i.max_replicas
            )
        })
        .collect::<Vec<_>>()
        .join(" ")
}

/// Execute one scale-down: drain the most-fragmented node's newest
/// replica.
pub(super) fn scale_down(state: &Arc<CoordinatorState>, reason: &'static str) -> Result<PlacementEvent> {
    let invs = inventories(state);
    let chosen = placement::drain_node(&invs)
        .ok_or_else(|| anyhow!("no node can give up a replica"))?
        .node_id
        .clone();
    let addr = state
        .nodes
        .read()
        .unwrap()
        .get(&chosen)
        .map(|e| e.announce.addr.clone())
        .ok_or_else(|| anyhow!("node {chosen} vanished mid-drain"))?;
    let resp =
        loadgen::request(&addr, "POST", "/v1/admin/scale-down", Some("{}"), SCALE_RPC_TIMEOUT)
            .with_context(|| format!("scale-down RPC to {chosen}"))?;
    if !(200..300).contains(&resp.status) {
        bail!("node {chosen} refused scale-down with {}: {}", resp.status, resp.body_str());
    }
    let replica_id = resp
        .json()
        .ok()
        .and_then(|j| j.get("retired").and_then(Json::as_usize))
        .unwrap_or(0) as u64;
    {
        let mut nodes = state.nodes.write().unwrap();
        if let Some(e) = nodes.get_mut(&chosen) {
            if let Some(s) = e.status.as_mut() {
                s.live_replicas = s.live_replicas.saturating_sub(1);
                s.gpu_memory_free = (s.gpu_memory_free + e.announce.replica_gpu_memory)
                    .min(e.announce.gpu_memory_total);
            }
        }
    }
    rebuild_router(state);
    state.metrics.note_retire(reason);
    let event = PlacementEvent {
        at: state.started.elapsed().as_secs_f64(),
        node_id: chosen.clone(),
        replica_id,
        reason,
        up: false,
    };
    crate::info!(
        "cluster",
        "drained replica {replica_id} from node {chosen} (reason: {reason})"
    );
    let (forecast_rps, forecast_wmape) = {
        let mut sup = state.supervisor.lock().unwrap();
        sup.scale_downs += 1;
        sup.events.push(event.clone());
        (sup.last_forecast, sup.forecast_error)
    };
    state.decisions.record(
        "coordinator",
        "retirement",
        reason,
        vec![
            ("node", chosen.clone()),
            ("replica_id", replica_id.to_string()),
            ("bin_packing", inventory_summary(&invs)),
            ("forecast_rps", format!("{forecast_rps:.3}")),
            ("forecast_wmape", format!("{forecast_wmape:.4}")),
        ],
    );
    Ok(event)
}

/// The cluster-wide supervisor: backfill first (a dead node's replicas
/// come back on survivors before anything else is considered), then the
/// forecast planner, then the reactive detector + queue guard.
fn supervisor_loop(state: &Arc<CoordinatorState>) {
    let policy = state.cfg.policy.clone();
    let calib_target = policy.calib_samples.max(20);
    let mut calib_frames: Vec<Frame> = Vec::new();
    let mut detector: Option<ZscoreDetector> = None;
    let mut streaks = Streaks::default();
    let mut last_action: Option<Instant> = None;
    let mut last_backfill: Option<Instant> = None;
    let mut last_snapshot: Option<Instant> = None;
    let mut last_defrag: Option<Instant> = None;
    // defrag is the lowest-priority act: well outside any scaling
    // cooldown, and never more than once per cooldown window
    let defrag_every = policy.cooldown.max(policy.sample_interval * 5);
    let mut forecaster = policy.forecast.as_ref().map(|p| {
        Forecaster::new(ForecastConfig {
            horizon: p.horizon_steps.max(1),
            season: p.season_steps,
            ..ForecastConfig::default()
        })
    });
    let mut learned_capacity = 0.0f64;

    loop {
        if sleep_interruptible(state, policy.sample_interval) {
            break;
        }
        let samples: Vec<NodeSample> = node_samples(state)
            .into_iter()
            .filter(|n| n.healthy && n.ready)
            .collect();
        let live: usize = samples.iter().map(|n| n.live_replicas).sum();
        if samples.is_empty() || live == 0 {
            continue;
        }

        // periodic engine checkpoints: keep one warm frame per serving
        // node so a dead node's capacity restores in milliseconds instead
        // of re-running engine init
        if !state.cfg.snapshot_interval.is_zero() {
            let due = last_snapshot
                .map(|t| t.elapsed() >= state.cfg.snapshot_interval)
                .unwrap_or(true);
            if due {
                let ids: Vec<&str> = samples
                    .iter()
                    .filter(|n| n.live_replicas > 0)
                    .map(|n| n.node_id.as_str())
                    .collect();
                super::migrate::capture_sweep(state, &ids);
                last_snapshot = Some(Instant::now());
            }
        }

        // the target ratchets up to the observed replica count (nodes may
        // register after the first tick) and is lowered only by explicit
        // scale-downs — so a node death leaves it high, which is exactly
        // the gap backfill closes
        let mut target = state.target_replicas.load(Ordering::Acquire);
        let observed = live.clamp(policy.min_replicas, policy.max_replicas);
        if observed > target {
            target = observed;
            state.target_replicas.store(target, Ordering::Release);
        }

        // backfill: a dead node dropped `live` under what the supervisor
        // wants. One placement per tick, spaced by two heartbeats so the
        // optimistic accounting has been confirmed by a real status.
        if live < target {
            let spaced = last_backfill
                .map(|t| t.elapsed() >= state.cfg.heartbeat_interval * 2)
                .unwrap_or(true);
            if spaced {
                // snapshot-first: restoring from the last periodic frame
                // beats a cold spawn by the whole engine-init time
                match snapshot_backfill(state) {
                    Ok(Some(_)) => last_backfill = Some(Instant::now()),
                    other => {
                        if let Err(e) = other {
                            crate::warn!(
                                "cluster",
                                "snapshot backfill failed, falling back to cold spawn: {e}"
                            );
                        }
                        match scale_up(state, "backfill") {
                            Ok(_) => last_backfill = Some(Instant::now()),
                            Err(e) => crate::warn!("cluster", "backfill placement failed: {e}"),
                        }
                    }
                }
            }
            continue; // restore capacity before planning on top of it
        }

        // ---- defrag: opportunistic rebalancing while otherwise idle —
        // capacity is whole (no backfill pending) and the fleet is
        // outside every scaling cooldown
        if policy.defrag {
            let cooled = last_action
                .map(|t| t.elapsed() >= policy.cooldown)
                .unwrap_or(true);
            let spaced = last_defrag.map(|t| t.elapsed() >= defrag_every).unwrap_or(true);
            if cooled && spaced {
                if let Some((source, target)) = placement::defrag_plan(&inventories(state)) {
                    crate::info!(
                        "cluster",
                        "defrag: migrating a replica {source} -> {target}"
                    );
                    let req = MigrationRequest {
                        source_node: source,
                        target_node: Some(target),
                    };
                    super::migrate::execute(state, &req, "defrag");
                    last_defrag = Some(Instant::now());
                }
            }
        }

        // cluster row: node frames (already per-replica means) weighted by
        // replica count, plus the summed arrival rate for the forecaster
        let mut acc = [0.0f64; 8];
        let mut weight = 0.0f64;
        let mut queue_wait = 0.0f64;
        let mut arrival_total = 0.0f64;
        for n in &samples {
            arrival_total += n.arrival_rps;
            queue_wait += n.queue_wait * n.live_replicas as f64;
        }
        let frames: Vec<(Frame, f64)> = {
            let nodes = state.nodes.read().unwrap();
            samples
                .iter()
                .filter_map(|n| {
                    let e = nodes.get(&n.node_id)?;
                    let f = e.status.as_ref()?.frame?;
                    Some((f, n.live_replicas as f64))
                })
                .collect()
        };
        for (f, w) in &frames {
            for (a, v) in acc.iter_mut().zip(f.to_array()) {
                *a += v * w;
            }
            weight += w;
        }
        if weight <= 0.0 {
            continue;
        }
        for a in acc.iter_mut() {
            *a /= weight;
        }
        let row = Frame::from_array(acc);
        let queue_wait = queue_wait / weight;

        // ---- proactive: the forecast planner over per-node capacities
        if let (Some(fp), Some(fc)) = (policy.forecast.as_ref(), forecaster.as_mut()) {
            let under_pressure = row.n_pending > 0.5 || row.gpu_util >= 0.9;
            if under_pressure && row.n_finished > learned_capacity {
                learned_capacity = row.n_finished;
            }
            fc.observe(arrival_total);
            let pred = fc.forecast(fp.horizon_steps.max(1));
            let err = fc.error();
            let degraded = fc.degraded(fp.err_budget);
            {
                let mut sup = state.supervisor.lock().unwrap();
                sup.last_forecast = pred.unwrap_or(0.0);
                sup.forecast_error = err.unwrap_or(0.0);
                sup.forecast_degraded = degraded;
            }
            let fallback = if fp.replica_capacity_rps > 0.0 {
                fp.replica_capacity_rps
            } else {
                learned_capacity
            };
            // per-node capacity in the planner: each node contributes
            // max_replicas slots at its advertised per-replica rate,
            // falling back to the configured/learned capacity
            let slots: Vec<f64> = {
                let nodes = state.nodes.read().unwrap();
                samples
                    .iter()
                    .flat_map(|n| {
                        let per = nodes
                            .get(&n.node_id)
                            .map(|e| e.announce.replica_capacity_rps)
                            .filter(|c| *c > 0.0)
                            .unwrap_or(fallback);
                        let max = nodes
                            .get(&n.node_id)
                            .map(|e| e.announce.max_replicas)
                            .unwrap_or(n.live_replicas);
                        std::iter::repeat(per).take(max)
                    })
                    .collect()
            };
            // capacity evidence can come from ANY source: node
            // advertisements count, so a fleet of self-describing nodes
            // plans proactively from the first tick instead of waiting
            // for an overload episode to learn from
            let trustworthy =
                !degraded && slots.iter().any(|c| *c >= MIN_CAPACITY_EVIDENCE);
            if let (Some(pred), true) = (pred, trustworthy) {
                let needed = replicas_for_cluster_rate(pred, &slots, fp.headroom, policy.min_replicas)
                    .min(policy.max_replicas);
                let cooled = last_action
                    .map(|t| t.elapsed() >= policy.cooldown)
                    .unwrap_or(true);
                if needed > live && cooled && live < policy.max_replicas {
                    match scale_up(state, "forecast") {
                        Ok(_) => {
                            crate::info!(
                                "cluster",
                                "proactive cluster scale-up: predicted {pred:.1} rps needs \
                                 {needed} replicas, {live} live"
                            );
                            state
                                .target_replicas
                                .store((live + 1).clamp(policy.min_replicas, policy.max_replicas), Ordering::Release);
                            last_action = Some(Instant::now());
                            streaks.note_fired(ScaleDirection::Up);
                            continue;
                        }
                        Err(e) => crate::warn!("cluster", "proactive placement failed: {e}"),
                    }
                }
            }
        }

        // ---- reactive: the detector + queue guard over the cluster row
        if !policy.detector_scaling {
            continue;
        }
        let Some(det) = &detector else {
            calib_frames.push(row);
            if calib_frames.len() >= calib_target {
                match ZscoreDetector::calibrate_frames(&calib_frames) {
                    Some(d) if d.threshold > 1e-9 => {
                        crate::info!(
                            "cluster",
                            "cluster detector calibrated on {} samples (threshold {:.3})",
                            calib_frames.len(),
                            d.threshold
                        );
                        state.supervisor.lock().unwrap().calibrated = true;
                        detector = Some(d);
                    }
                    _ => {
                        let cap = calib_target * 50;
                        if calib_frames.len() > cap {
                            calib_frames.drain(..calib_frames.len() - cap / 2);
                        }
                    }
                }
            }
            continue;
        };
        let d = det.detect_frame(&row);
        streaks.observe(&d, queue_wait, policy.queue_wait_budget.as_secs_f64());
        let cooled = last_action
            .map(|t| t.elapsed() >= policy.cooldown)
            .unwrap_or(true);
        if !cooled {
            continue;
        }
        let Some((direction, trigger)) = streaks.decide(policy.patience) else {
            continue;
        };
        let reason = match trigger {
            Trigger::QueueWait => "queue_wait",
            _ => "detector",
        };
        match direction {
            ScaleDirection::Up if live < policy.max_replicas => {
                match scale_up(state, reason) {
                    Ok(_) => {
                        state.target_replicas.store(
                            (live + 1).clamp(policy.min_replicas, policy.max_replicas),
                            Ordering::Release,
                        );
                        last_action = Some(Instant::now());
                        streaks.note_fired(direction);
                    }
                    Err(e) => crate::warn!("cluster", "reactive placement failed: {e}"),
                }
                streaks.reset();
            }
            ScaleDirection::Down if live > policy.min_replicas => {
                match scale_down(state, reason) {
                    Ok(_) => {
                        state.target_replicas.store(
                            live.saturating_sub(1).max(policy.min_replicas),
                            Ordering::Release,
                        );
                        last_action = Some(Instant::now());
                        streaks.note_fired(direction);
                    }
                    Err(e) => crate::warn!("cluster", "cluster drain failed: {e}"),
                }
                streaks.reset();
            }
            _ => streaks.reset(),
        }
    }
}

/// Sleep `total` in short slices; true means the coordinator is stopping.
fn sleep_interruptible(state: &CoordinatorState, total: Duration) -> bool {
    let deadline = Instant::now() + total;
    loop {
        if state.stop.load(Ordering::Acquire) {
            return true;
        }
        match deadline.checked_duration_since(Instant::now()) {
            None => return false,
            Some(rem) => std::thread::sleep(rem.min(Duration::from_millis(20))),
        }
    }
}
