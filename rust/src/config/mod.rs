//! The ENOVA **service configuration module** (§IV-A): derives every
//! Table I knob from monitoring metrics instead of heuristics.
//!
//! * `max_num_seqs` — eq. 4/5: OLS of n^f on n^r + slope t-test decides
//!   whether the service is saturated; n_limit/t^r_limit then come from an
//!   extreme-value (Gumbel) or KDE estimate of the window.
//! * `gpu_memory` / `parallel_size` — eq. 6: OLS of m^u on n^r,
//!   extrapolated to `max_num_seqs`, mapped onto the device catalog.
//! * `max_tokens` — §IV-A-3: per-community KDE quantile of output lengths
//!   (communities come from [`crate::clusterer`]).
//! * `replicas` / `weights` — eq. 8: cost-minimizing LP over GPU types with
//!   capacity and inventory constraints; weights ∝ per-type n_limit.

use crate::metrics::Frame;
use crate::simulator::gpu::GpuSpec;
use crate::simulator::modelcard::ModelCard;
use crate::simulator::replica::{Replica, ServiceConfig};
use crate::stats::{evt, kde::Kde, lp, ols};

/// Saturation verdict from eq. 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Saturation {
    /// n^f still responds to n^r — the service has headroom; the observed
    /// maxima UNDER-estimate n_limit, so extrapolate with extreme values.
    Unsaturated,
    /// no significant relationship — n^f fluctuates around n_limit.
    Saturated,
}

#[derive(Debug, Clone, Copy)]
pub struct MaxNumSeqsDecision {
    pub saturation: Saturation,
    /// estimated max sustainable finished-requests/second
    pub n_limit: f64,
    /// estimated execution time per request at the limit (s)
    pub t_limit: f64,
    pub max_num_seqs: usize,
    /// p-value of the OLS slope t-test
    pub p_value: f64,
}

/// Significance level of the slope t-test (eq. 5).
pub const ALPHA: f64 = 0.01;

/// §IV-A-1. `frames` is the monitoring window `[t-w, t]` at 1 Hz/1-min.
pub fn determine_max_num_seqs(frames: &[Frame]) -> Option<MaxNumSeqsDecision> {
    // Only busy observations are informative about capacity.
    let busy: Vec<&Frame> = frames.iter().filter(|f| f.n_running >= 1.0).collect();
    if busy.len() < 12 {
        return None;
    }
    let nr: Vec<f64> = busy.iter().map(|f| f.n_running).collect();
    let nf: Vec<f64> = busy.iter().map(|f| f.n_finished).collect();
    let tr: Vec<f64> = busy
        .iter()
        .map(|f| f.t_request)
        .filter(|&t| t > 0.0)
        .collect();
    if tr.is_empty() {
        return None;
    }

    let fit = ols::fit(&nr, &nf);
    let saturation = match &fit {
        Some(f) if f.significant(ALPHA) && f.slope > 0.0 => Saturation::Unsaturated,
        _ => Saturation::Saturated,
    };
    let p_value = fit.map(|f| f.p_value).unwrap_or(1.0);

    let (n_limit, t_limit) = match saturation {
        Saturation::Unsaturated => {
            // extreme-value extrapolation beyond the observed window
            let g = evt::Gumbel::fit(&nf)?;
            let n = g.quantile(0.99).max(crate::stats::descriptive::max(&nf));
            let gt = evt::Gumbel::fit(&tr)?;
            (n, gt.quantile(0.90))
        }
        Saturation::Saturated => {
            // the window already samples the limit: KDE of the bulk
            let kn = Kde::fit(&nf)?;
            let kt = Kde::fit(&tr)?;
            (kn.quantile(0.95), kt.quantile(0.90))
        }
    };
    if n_limit <= 0.0 || t_limit <= 0.0 {
        return None;
    }
    let max_num_seqs = (n_limit * t_limit).ceil().max(1.0) as usize;
    Some(MaxNumSeqsDecision {
        saturation,
        n_limit,
        t_limit,
        max_num_seqs,
        p_value,
    })
}

#[derive(Debug, Clone, Copy)]
pub struct GpuMemoryDecision {
    /// vLLM-style gpu_memory_utilization fraction
    pub gpu_memory: f64,
    pub parallel_size: usize,
    /// OLS slope of m^u on n^r (memory per concurrent request)
    pub mem_per_seq: f64,
}

/// §IV-A-2: m^u = g(n^r), evaluated at `max_num_seqs`, then mapped onto a
/// concrete device (weights must fit, KV for the target batch must fit).
pub fn determine_gpu_memory(
    frames: &[Frame],
    max_num_seqs: usize,
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
) -> GpuMemoryDecision {
    // parallel_size: smallest power of two whose pooled memory holds the
    // weights plus a KV floor
    let mut parallel_size = 1usize;
    while parallel_size < 64 {
        let pooled = gpu.mem_bytes * parallel_size as f64 * 0.95;
        let floor = model.weight_bytes() * 1.03
            + model.kv_bytes_per_token() * 128.0 * max_num_seqs.min(8) as f64;
        if pooled > floor {
            break;
        }
        parallel_size *= 2;
    }

    let busy: Vec<&Frame> = frames.iter().filter(|f| f.n_running >= 1.0).collect();
    let fit = if busy.len() >= 12 {
        let nr: Vec<f64> = busy.iter().map(|f| f.n_running).collect();
        let mu: Vec<f64> = busy.iter().map(|f| f.mem_util).collect();
        ols::fit(&nr, &mu)
    } else {
        None
    };
    let (gpu_memory, mem_per_seq) = match fit {
        Some(f) if f.slope >= 0.0 => {
            // extrapolate utilization to the recommended concurrency,
            // +5% headroom, clamped to the practical vLLM range
            let projected = f.predict(max_num_seqs as f64) + 0.05;
            (projected.clamp(0.5, 0.95), f.slope)
        }
        _ => (0.9, 0.0),
    };
    GpuMemoryDecision {
        gpu_memory,
        parallel_size,
        mem_per_seq,
    }
}

/// §IV-A-3: per-community max_tokens = KDE quantile of observed output
/// lengths (q=0.99 keeps virtually all well-formed answers un-truncated
/// while bounding runaway generations).
pub const MAX_TOKENS_QUANTILE: f64 = 0.99;

pub fn determine_max_tokens(output_lens: &[f64]) -> Option<usize> {
    if output_lens.len() < 8 {
        return None;
    }
    let kde = Kde::fit(output_lens)?;
    Some(kde.quantile(MAX_TOKENS_QUANTILE).ceil().max(8.0) as usize)
}

/// One GPU-type option for the replica plan (eq. 8).
#[derive(Debug, Clone)]
pub struct GpuOption {
    pub gpu: &'static GpuSpec,
    /// per-replica sustainable req/s on this GPU type (estimated n_limit)
    pub n_limit: f64,
    pub parallel_size: usize,
    /// total devices of this type in inventory (N^i)
    pub inventory: usize,
    /// required gpu_memory fraction on this type
    pub gpu_memory: f64,
}

#[derive(Debug, Clone)]
pub struct ReplicaPlan {
    /// replicas per GPU option (same order as input)
    pub replicas: Vec<usize>,
    /// routing weights ∝ n_limit, aligned with `replicas`
    pub weights: Vec<f64>,
    pub total_cost: f64,
}

/// Matching score (paper: distance between required gpu_memory and device
/// memory, i.e. prefer the cheapest device that wastes the least memory).
pub fn matching_score(opt: &GpuOption, model: &ModelCard) -> f64 {
    let group_mem = opt.gpu.mem_bytes * opt.parallel_size as f64;
    let required = model.weight_bytes() * 1.03 / opt.gpu_memory;
    let waste = ((group_mem - required) / group_mem).max(0.0);
    let cost = opt.gpu.usd_per_hour * opt.parallel_size as f64;
    cost * (1.0 + waste)
}

/// §IV-A-4 / eq. 8: choose replica counts minimizing Σ score·replicas s.t.
/// Σ n_limit·replicas ≥ demand and parallel_size·replicas ≤ inventory.
pub fn determine_replicas(
    options: &[GpuOption],
    model: &ModelCard,
    demand_rps: f64,
) -> Option<ReplicaPlan> {
    let scores: Vec<f64> = options.iter().map(|o| matching_score(o, model)).collect();
    // LP relaxation: minimize score·x. The coverage constraint
    // Σ n_limit x ≥ demand has b < 0 in ≤-form, so flip it into the
    // objective via a large feasibility search instead: solve the LP with
    // only inventory bounds, then integer-search around the cover.
    let upper: Vec<usize> = options
        .iter()
        .map(|o| o.inventory / o.parallel_size.max(1))
        .collect();
    // initial guess: greedily satisfy demand with best score/n_limit ratio
    let mut order: Vec<usize> = (0..options.len()).collect();
    order.sort_by(|&a, &b| {
        (scores[a] / options[a].n_limit.max(1e-9))
            .total_cmp(&(scores[b] / options[b].n_limit.max(1e-9)))
    });
    let mut greedy = vec![0usize; options.len()];
    let mut covered = 0.0;
    for &i in &order {
        while covered < demand_rps && greedy[i] < upper[i] {
            greedy[i] += 1;
            covered += options[i].n_limit;
        }
    }
    if covered < demand_rps {
        return None; // inventory cannot satisfy demand
    }
    let relaxed: Vec<f64> = greedy.iter().map(|&x| x as f64).collect();
    let feasible = |x: &[usize]| -> bool {
        let cap: f64 = x
            .iter()
            .zip(options)
            .map(|(&n, o)| n as f64 * o.n_limit)
            .sum();
        cap >= demand_rps && x.iter().zip(&upper).all(|(&n, &u)| n <= u)
    };
    let objective = |x: &[usize]| -> f64 {
        x.iter()
            .zip(&scores)
            .map(|(&n, s)| n as f64 * s)
            .sum()
    };
    let best = lp::integer_refine(&relaxed, &upper, feasible, objective)?;
    let total_cost = objective(&best);
    let weights: Vec<f64> = best
        .iter()
        .zip(options)
        .map(|(&n, o)| if n > 0 { o.n_limit } else { 0.0 })
        .collect();
    // normalize weights so the strongest type gets 1.0 (Table III format)
    let wmax = weights.iter().copied().fold(0.0, f64::max).max(1e-9);
    Some(ReplicaPlan {
        replicas: best,
        weights: weights.into_iter().map(|w| w / wmax).collect(),
        total_cost,
    })
}

/// End-to-end recommendation for one (model, GPU) pair: profile the
/// replica on a calibration workload via the simulator, then run the full
/// §IV-A pipeline. This is what the benches call for Table III / Fig. 4.
pub fn recommend_for(
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
    calibration_frames: &[Frame],
    output_lens: &[f64],
) -> ServiceConfig {
    let mns = determine_max_num_seqs(calibration_frames);
    let max_num_seqs = mns.map(|d| d.max_num_seqs).unwrap_or(8);
    let gm = determine_gpu_memory(calibration_frames, max_num_seqs, gpu, model);
    let max_tokens =
        determine_max_tokens(output_lens).unwrap_or(model.max_model_tokens);
    // clamp concurrency to what the KV budget at this gpu_memory supports
    let probe = Replica::new(
        gpu,
        model,
        ServiceConfig {
            max_num_seqs,
            gpu_memory: gm.gpu_memory,
            max_tokens,
            parallel_size: gm.parallel_size,
        },
    );
    let mean_ctx = 256.0 + max_tokens as f64 * 0.5;
    let kv_cap = (probe.kv_budget_bytes() / (model.kv_bytes_per_token() * mean_ctx))
        .floor()
        .max(1.0) as usize;
    ServiceConfig {
        max_num_seqs: max_num_seqs.min(kv_cap).max(1),
        gpu_memory: gm.gpu_memory,
        max_tokens,
        parallel_size: gm.parallel_size,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gpu::{A100_80G, RTX4090_24G};
    use crate::simulator::modelcard::{LLAMA2_70B, LLAMA2_7B};
    use crate::util::rng::Pcg64;

    fn frames_linear(n: usize, slope: f64, noise: f64, seed: u64) -> Vec<Frame> {
        // n^f responds linearly to n^r (unsaturated service)
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|i| {
                let nr = 1.0 + (i % 32) as f64;
                Frame {
                    n_running: nr,
                    n_finished: (slope * nr + rng.normal() * noise).max(0.0),
                    t_request: 4.0 + rng.normal() * 0.3,
                    mem_util: (0.4 + 0.004 * nr + rng.normal() * 0.01).clamp(0.0, 1.0),
                    ..Default::default()
                }
            })
            .collect()
    }

    fn frames_saturated(n: usize, n_limit: f64, seed: u64) -> Vec<Frame> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|i| Frame {
                n_running: 20.0 + (i % 24) as f64,
                n_finished: (n_limit + rng.normal() * 0.4).max(0.0),
                t_request: 6.0 + rng.normal() * 0.4,
                mem_util: (0.85 + rng.normal() * 0.01).clamp(0.0, 1.0),
                ..Default::default()
            })
            .collect()
    }

    #[test]
    fn saturation_detection() {
        let d = determine_max_num_seqs(&frames_linear(200, 0.8, 0.5, 1)).unwrap();
        assert_eq!(d.saturation, Saturation::Unsaturated);
        let d = determine_max_num_seqs(&frames_saturated(200, 7.0, 2)).unwrap();
        assert_eq!(d.saturation, Saturation::Saturated);
        assert!((d.n_limit - 7.0).abs() < 1.0, "n_limit {}", d.n_limit);
        // eq. 4: max_num_seqs ≈ n_limit · t_limit ≈ 7 · 6 ≈ 42
        assert!((30..60).contains(&d.max_num_seqs), "{}", d.max_num_seqs);
    }

    #[test]
    fn too_little_data_is_refused() {
        assert!(determine_max_num_seqs(&frames_linear(6, 1.0, 0.1, 3)).is_none());
    }

    #[test]
    fn gpu_memory_extrapolates_occupancy() {
        let frames = frames_linear(300, 0.9, 0.4, 4);
        let gm = determine_gpu_memory(&frames, 64, &A100_80G, &LLAMA2_7B);
        // slope 0.004/seq × 64 seqs + base 0.4 + headroom ≈ 0.71
        assert!((0.6..0.85).contains(&gm.gpu_memory), "{}", gm.gpu_memory);
        assert_eq!(gm.parallel_size, 1);
        let gm70 = determine_gpu_memory(&frames, 16, &A100_80G, &LLAMA2_70B);
        assert!(gm70.parallel_size >= 2, "70B needs TP>1");
        let gm70_4090 = determine_gpu_memory(&frames, 16, &RTX4090_24G, &LLAMA2_70B);
        assert!(gm70_4090.parallel_size >= 8, "70B on 24GB needs TP≥8");
    }

    #[test]
    fn gpu_memory_degenerate_windows_fall_back_to_defaults() {
        // all-idle window: no busy frames at all — the fit is refused and
        // the conservative vLLM default comes back
        let idle: Vec<Frame> = (0..200)
            .map(|_| Frame {
                n_running: 0.0,
                n_finished: 0.0,
                mem_util: 0.4,
                ..Default::default()
            })
            .collect();
        let gm = determine_gpu_memory(&idle, 64, &A100_80G, &LLAMA2_7B);
        assert_eq!(gm.gpu_memory, 0.9, "idle window must use the default");
        assert_eq!(gm.mem_per_seq, 0.0);
        assert_eq!(gm.parallel_size, 1);

        // constant n_running: zero x-variance, OLS refuses the fit — no
        // extrapolation from a window that never varied occupancy
        let constant: Vec<Frame> = (0..200)
            .map(|i| Frame {
                n_running: 16.0,
                n_finished: 5.0,
                mem_util: 0.5 + 0.001 * (i % 7) as f64,
                ..Default::default()
            })
            .collect();
        let gm = determine_gpu_memory(&constant, 64, &A100_80G, &LLAMA2_7B);
        assert_eq!(gm.gpu_memory, 0.9, "constant occupancy must use the default");
        assert_eq!(gm.mem_per_seq, 0.0);

        // single busy sample: far under the 12-frame evidence floor
        let single = vec![Frame {
            n_running: 3.0,
            n_finished: 2.0,
            mem_util: 0.6,
            t_request: 1.0,
            ..Default::default()
        }];
        let gm = determine_gpu_memory(&single, 8, &A100_80G, &LLAMA2_7B);
        assert_eq!(gm.gpu_memory, 0.9, "one sample is not evidence");
        assert_eq!(gm.mem_per_seq, 0.0);
        // the TP sizing still works off the model/device alone
        let gm70 = determine_gpu_memory(&single, 16, &RTX4090_24G, &LLAMA2_70B);
        assert!(gm70.parallel_size >= 8);

        // a negative memory/occupancy slope (monitoring noise) is also
        // refused rather than extrapolated below the observed window
        let mut rng = Pcg64::new(11);
        let negative: Vec<Frame> = (0..100)
            .map(|i| {
                let nr = 1.0 + (i % 24) as f64;
                Frame {
                    n_running: nr,
                    n_finished: nr * 0.8,
                    mem_util: (0.9 - 0.01 * nr + rng.normal() * 1e-4).clamp(0.0, 1.0),
                    ..Default::default()
                }
            })
            .collect();
        let gm = determine_gpu_memory(&negative, 64, &A100_80G, &LLAMA2_7B);
        assert_eq!(gm.gpu_memory, 0.9, "negative slope must not extrapolate");
    }

    #[test]
    fn max_num_seqs_degenerate_windows_are_refused() {
        // the same degenerate windows must make the §IV-A-1 estimator
        // abstain entirely (the supervisor's reconfig loop relies on this
        // to hold steady at idle)
        let idle: Vec<Frame> = (0..200).map(|_| Frame::default()).collect();
        assert!(determine_max_num_seqs(&idle).is_none(), "all-idle window");

        let single = vec![Frame {
            n_running: 3.0,
            n_finished: 2.0,
            t_request: 1.0,
            ..Default::default()
        }];
        assert!(determine_max_num_seqs(&single).is_none(), "single sample");

        // busy frames but no latency evidence (t_request all zero)
        let no_latency: Vec<Frame> = (0..50)
            .map(|i| Frame {
                n_running: 1.0 + (i % 5) as f64,
                n_finished: 2.0,
                t_request: 0.0,
                ..Default::default()
            })
            .collect();
        assert!(determine_max_num_seqs(&no_latency).is_none(), "no latency");
    }

    #[test]
    fn max_tokens_tracks_q99() {
        let mut rng = Pcg64::new(5);
        let lens: Vec<f64> = (0..5000).map(|_| rng.lognormal(5.07, 0.42)).collect();
        let mt = determine_max_tokens(&lens).unwrap();
        assert!((330..520).contains(&mt), "gsm8k-like max_tokens {mt}");
        assert!(determine_max_tokens(&[1.0; 3]).is_none());
    }

    #[test]
    fn replica_plan_prefers_cost_effective_mix() {
        let options = vec![
            GpuOption {
                gpu: &A100_80G,
                n_limit: 12.0,
                parallel_size: 1,
                inventory: 8,
                gpu_memory: 0.9,
            },
            GpuOption {
                gpu: &RTX4090_24G,
                n_limit: 5.0,
                parallel_size: 1,
                inventory: 8,
                gpu_memory: 0.9,
            },
        ];
        let plan = determine_replicas(&options, &LLAMA2_7B, 20.0).unwrap();
        let cap: f64 = plan
            .replicas
            .iter()
            .zip(&options)
            .map(|(&n, o)| n as f64 * o.n_limit)
            .sum();
        assert!(cap >= 20.0, "plan under-covers: {plan:?}");
        // 4090s are 5× cheaper per rps here, so they should dominate
        assert!(plan.replicas[1] > 0);
        // weights normalized to the strongest type
        let wmax = plan.weights.iter().copied().fold(0.0, f64::max);
        assert!((wmax - 1.0).abs() < 1e-9);
    }

    #[test]
    fn replica_plan_fails_on_insufficient_inventory() {
        let options = vec![GpuOption {
            gpu: &A100_80G,
            n_limit: 2.0,
            parallel_size: 1,
            inventory: 2,
            gpu_memory: 0.9,
        }];
        assert!(determine_replicas(&options, &LLAMA2_7B, 50.0).is_none());
    }

    #[test]
    fn recommend_for_end_to_end_shape() {
        // calibrate from an actual simulator run so the pipeline sees
        // realistic frames
        use crate::simulator::replica::{Replica, ServiceConfig};
        use crate::workload::arrivals::{poisson_stream, RateProfile};
        use crate::workload::corpus::{CorpusMix, ALL_FAMILIES};
        let mut rng = Pcg64::new(6);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(9.0), &mix, 300.0, &mut rng);
        let probe = Replica::new(
            &A100_80G,
            &LLAMA2_7B,
            ServiceConfig {
                max_num_seqs: 256,
                gpu_memory: 0.9,
                max_tokens: 2048,
                parallel_size: 1,
            },
        );
        let res = probe.simulate(arrivals, 420.0);
        let frames: Vec<Frame> = res.frames.iter().map(|&(_, f)| f).collect();
        let lens: Vec<f64> = res.finished.iter().map(|f| f.out_len as f64).collect();
        let cfg = recommend_for(&A100_80G, &LLAMA2_7B, &frames, &lens);
        assert!(cfg.max_num_seqs >= 8, "{cfg:?}");
        assert!(cfg.max_tokens < 2048, "should cap runaway tokens: {cfg:?}");
        assert!((0.5..=0.95).contains(&cfg.gpu_memory));
    }
}
