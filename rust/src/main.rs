//! ENOVA leader binary: deployment, monitoring and autoscaling for
//! serverless LLM serving. Subcommands exercise the public API; the
//! examples/benches are the full experiment drivers.

use enova::util::cli::Args;

const USAGE: &str = "\
enova — autoscaling towards cost-effective and stable serverless LLM serving

USAGE: enova <COMMAND> [OPTIONS]

  --config enova.toml layers file settings under the flags for the serving
  roles (serve-http / node / serve-http --cluster): file values are
  defaults, explicit flags win. `[tenants.NAME]` sections define the
  multi-tenant roster (tier = latency|standard|batch, rate_limit,
  rate_burst, queue_budget_ms, api_keys).

COMMANDS:
  serve       serve prompts on the compiled tiny LM (options: --prompts N --max-tokens N)
  serve-http  OpenAI-compatible HTTP gateway (--port 8080 --replicas 2 --engine auto|lm|sim
              --max-num-seqs N --max-tokens N --max-pending N --rate RPS --burst N
              --http-workers N --ingress reactor|threaded --sim-delay-ms N --host ADDR
              --queue-budget-ms N
              --warm-pool N --log-json --trace-sample F --trace-slo-ms N
              --autoscale [--min-replicas N --max-replicas N --scale-interval-ms N
              --calib-samples N --patience N --cooldown-ms N --queue-wait-budget-ms N]
              --reconfig [--reconfig-interval-ms N --reconfig-cooldown-ms N
              --reconfig-deadband F --reconfig-min-seqs N --reconfig-max-seqs N
              --reconfig-window N]
              --forecast [--forecast-horizon-ms N --forecast-err-budget F
              --forecast-season-ms N --forecast-capacity RPS --forecast-headroom F
              --forecast-min-warm N --trough-scale-down])
              seeded fault injection (chaos drills; see also POST /v1/admin/chaos):
              [--chaos-seed N --chaos-error-rate F --chaos-latency-rate F
              --chaos-latency-ms F --chaos-latency-sigma F --chaos-sse-abort-rate F
              --chaos-degrade-period-s F --chaos-degrade-duty F --chaos-degrade-factor F]
              --legacy-api on|off keeps (default) or sunsets the pre-/v1 alias
              routes; sunset aliases answer 410 with a structured error, and
              every alias hit is counted in enova_api_deprecated_requests_total
              --sim-spawn-delay-ms N adds an artificial engine-init delay to
              sim-engine cold spawns (makes snapshot restores measurably faster)
              distributed plane: --cluster turns this process into the cluster
              coordinator (ingress + heartbeats + cross-node placement; no local
              engines): [--heartbeat-ms N --node-timeout-beats N
              --dispatch-attempts N] plus the --autoscale/--forecast supervisor
              flags above, now scoped cluster-wide, and per-node circuit
              breakers [--breaker-window N (0 disables) --breaker-min-samples N
              --breaker-error-threshold F --breaker-latency-ms N
              --breaker-cooldown-ms N --breaker-probes N]; snapshot/migration
              lifecycle (/v1/admin/{snapshots,migrate,migrations}):
              [--snapshot-interval-ms N (0 disables the periodic capture sweep)
              --defrag (idle-time live-migration defragmentation)]
  node        one serving node of the distributed plane: the gateway plus the
              /cluster/* control surface, registering with a coordinator
              (--coordinator HOST:PORT --node-id NAME --gpu-memory F
              --replica-gpu-memory F --node-max-replicas N --capacity-rps F
              --announce-ms N --advertise HOST:PORT + the serve-http engine
              flags: --engine --replicas --port --warm-pool ... and the
              --chaos-* fault-injection flags above)
  loadgen     load against a gateway (--addr HOST:PORT [--report FILE] [--strict];
              closed loop: --concurrency N --requests N --max-tokens N;
              open-loop scenarios: --scenario steady|diurnal|spike|ramp|mixture
              --duration-s F --base-rps F --peak-rps F --period-s F --spike-start F
              --spike-len F --seed N --workers N;
              misbehaving clients alongside either mode: --adversarial
              all|slow-loris,sse-disconnect --adversarial-clients N --chaos-seed N)
  bench-gateway  in-process scenario benchmark (--report FILE --baseline FILE
              --scenarios a,b,c --duration-s F --regression-pct F
              [--no-cluster-bench to skip the 2-node cluster scenario]
              [--no-saturation-bench to skip the reactor-vs-threaded
              max-throughput rows; --saturation-s F sets their duration])
  recommend   run the service configuration module for --model <name> --gpu <name>
  detect      calibrate + run the performance detector on the trace dataset
  simulate    simulate a replica (--model --gpu --rps --seconds --max-num-seqs)
  info        print artifact manifest summary
";

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env_known(&[
        "verbose",
        "autoscale",
        "reconfig",
        "strict",
        "forecast",
        "cluster",
        "trough-scale-down",
        "defrag",
        "no-cluster-bench",
        "no-saturation-bench",
        "log-json",
    ]);
    if args.flag("log-json") {
        enova::util::log::set_json(true);
    }
    let cmd = args.subcommand();
    // `--config enova.toml`: layered settings. File values become
    // defaults for the serving roles, explicit flags always win; the
    // process role itself (serve-http / --cluster / node) stays a
    // command-line decision. `[tenants.*]` sections become the tenant
    // registry of whichever role starts.
    let settings = match args.get("config") {
        Some(path) => enova::settings::EnovaConfig::load(path)?,
        None => enova::settings::EnovaConfig::default(),
    };
    let role = match cmd.as_str() {
        "serve-http" if args.flag("cluster") => "coordinator",
        "serve-http" => "gateway",
        "node" => "node",
        _ => "",
    };
    if !role.is_empty() {
        settings.apply(role, &mut args);
    }
    match cmd.as_str() {
        "serve" => serve(&args),
        "serve-http" => serve_http(&args, &settings.tenants),
        "node" => node_cmd(&args, &settings.tenants),
        "loadgen" => loadgen_cmd(&args),
        "bench-gateway" => bench_gateway(&args),
        "recommend" => recommend(&args),
        "detect" => detect(&args),
        "simulate" => simulate(&args),
        "info" => info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

#[cfg(feature = "xla-runtime")]
fn info() -> anyhow::Result<()> {
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    println!("artifacts: {}", m.dir.display());
    println!(
        "model: {} params, batch {}, ctx {}, vocab {} ({} / {})",
        m.model.param_count, m.model.batch, m.model.max_seq, m.model.vocab,
        m.model.decode_file, m.model.prefill_file
    );
    println!("vae: {} ({} features)", m.vae.file, m.vae.n_features);
    println!("embed: {} ({}→{})", m.embed.file, m.embed.hash_dim, m.embed.embed_dim);
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn info() -> anyhow::Result<()> {
    anyhow::bail!("`info` reads the AOT artifact manifest; rebuild with the `xla-runtime` feature")
}

#[cfg(feature = "xla-runtime")]
fn serve(args: &Args) -> anyhow::Result<()> {
    use enova::engine::{Engine, EngineConfig};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    let n = args.get_usize("prompts", 8);
    let max_tokens = args.get_usize("max-tokens", 24);
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    let rt = enova::runtime::PjRt::cpu()?;
    let lm = LmRuntime::load(rt, &m, ExecMode::Chained)?;
    let mut engine = Engine::new(
        lm,
        EngineConfig { max_num_seqs: 8, max_tokens, temperature: 0.7 },
        1,
    );
    let mut rng = enova::util::rng::Pcg64::new(2);
    for _ in 0..n {
        let fam = *rng.choice(&enova::workload::corpus::ALL_FAMILIES);
        let item = enova::workload::corpus::sample_item(fam, &mut rng);
        engine.submit(&item.text, max_tokens);
    }
    let t0 = std::time::Instant::now();
    let done = engine.run_to_completion()?;
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    println!(
        "served {} requests / {tokens} tokens in {:.2}s",
        done.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn serve(_args: &Args) -> anyhow::Result<()> {
    anyhow::bail!("`serve` drives the compiled tiny LM; rebuild with the `xla-runtime` feature")
}

/// Which engine `--engine auto` resolves to: the compiled LM when the
/// build has the runtime and the artifacts exist, the sim engine
/// otherwise.
fn auto_engine_kind() -> &'static str {
    #[cfg(feature = "xla-runtime")]
    {
        if enova::runtime::Manifest::artifacts_exist() {
            return "lm";
        }
        eprintln!("artifacts not found; serving with the deterministic sim engine");
    }
    #[cfg(not(feature = "xla-runtime"))]
    eprintln!("built without the xla-runtime feature; serving with the deterministic sim engine");
    "sim"
}

/// Reusable spawner for compiled-LM replicas (supervisor hot-add path).
#[cfg(feature = "xla-runtime")]
fn lm_spawner(
    max_num_seqs: usize,
    max_tokens: usize,
    temperature: f64,
) -> enova::gateway::EngineSpawner {
    use enova::engine::{Engine, EngineConfig, StreamEngine};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    std::sync::Arc::new(move |id| {
        let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
        let rt = enova::runtime::PjRt::cpu()?;
        let lm = LmRuntime::load(rt, &m, ExecMode::Chained)?;
        let cfg = EngineConfig {
            max_num_seqs,
            max_tokens,
            temperature,
        };
        Ok(Box::new(Engine::new(lm, cfg, 100 + id)) as Box<dyn StreamEngine>)
    })
}

/// Stub: `--engine lm` is rejected before this can ever be called.
#[cfg(not(feature = "xla-runtime"))]
fn lm_spawner(
    _max_num_seqs: usize,
    _max_tokens: usize,
    _temperature: f64,
) -> enova::gateway::EngineSpawner {
    std::sync::Arc::new(|_id| {
        Err(anyhow::anyhow!(
            "this binary was built without the xla-runtime feature"
        ))
    })
}

/// Build the reusable engine spawner the `serve-http` and `node`
/// subcommands share, from the engine CLI flags. Returns the spawner and
/// the resolved engine kind.
fn spawner_from_args(
    args: &Args,
) -> anyhow::Result<(enova::gateway::EngineSpawner, &'static str)> {
    use enova::engine::sim::{SimEngine, SimEngineConfig};
    use enova::engine::StreamEngine;
    use enova::gateway::EngineSpawner;
    use std::sync::Arc;
    use std::time::Duration;

    let max_num_seqs = args.get_usize("max-num-seqs", 8);
    let max_tokens = args.get_usize("max-tokens", 64);
    let temperature = args.get_f64("temperature", 0.7);
    let sim_delay = Duration::from_millis(args.get_usize("sim-delay-ms", 0) as u64);
    let spawn_delay = Duration::from_millis(args.get_usize("sim-spawn-delay-ms", 0) as u64);

    let engine_kind = match args.get_or("engine", "auto") {
        "auto" => auto_engine_kind(),
        "lm" => "lm",
        "sim" => "sim",
        other => anyhow::bail!("--engine must be auto, lm or sim (got {other:?})"),
    };
    #[cfg(not(feature = "xla-runtime"))]
    anyhow::ensure!(
        engine_kind != "lm",
        "--engine lm needs the xla-runtime feature (rebuild with default features)"
    );

    // a reusable spawner (not one-shot factories) so the supervisor can
    // hot-add replicas beyond the initial set and pre-warm standbys
    let spawner: EngineSpawner = if engine_kind == "lm" {
        lm_spawner(max_num_seqs, max_tokens, temperature)
    } else {
        Arc::new(move |_id| {
            // an artificial engine-init cost for the sim engine, so cold
            // spawns are measurably slower than snapshot restores (which
            // rebuild from the frame and never pay this)
            if !spawn_delay.is_zero() {
                std::thread::sleep(spawn_delay);
            }
            Ok(Box::new(SimEngine::new(SimEngineConfig {
                max_num_seqs,
                max_tokens,
                step_delay: sim_delay,
            })) as Box<dyn StreamEngine>)
        })
    };
    Ok((spawner, engine_kind))
}

/// `--legacy-api on|off` (default on): whether the pre-`/v1` alias routes
/// still answer. Off turns them into 410 structured errors; either way
/// every alias hit is counted and answered with `Deprecation`/`Sunset`
/// headers.
fn legacy_api_from_args(args: &Args) -> anyhow::Result<bool> {
    match args.get_or("legacy-api", "on") {
        "on" => Ok(true),
        "off" => Ok(false),
        other => anyhow::bail!("--legacy-api must be on or off (got {other:?})"),
    }
}

/// The request-tracing knobs (`--trace-sample F --trace-slo-ms N`) shared
/// by the gateway, the node and the coordinator.
fn trace_settings_from_args(args: &Args) -> enova::trace::TraceSettings {
    enova::trace::TraceSettings {
        sample_rate: args.get_f64("trace-sample", 1.0).clamp(0.0, 1.0),
        slo: std::time::Duration::from_millis(args.get_usize("trace-slo-ms", 2000) as u64),
        ..enova::trace::TraceSettings::default()
    }
}

/// `--ingress reactor|threaded`, shared by the gateway, the coordinator
/// and the node.
fn ingress_from_args(args: &Args) -> anyhow::Result<enova::gateway::IngressMode> {
    let spelling = args.get_or("ingress", "reactor");
    enova::gateway::IngressMode::parse(spelling).ok_or_else(|| {
        anyhow::anyhow!("unknown --ingress {spelling:?}; expected reactor or threaded")
    })
}

/// The seeded fault-injection knobs (`--chaos-*`) shared by the gateway
/// and the node. All rates default to 0, so the injector boots disarmed
/// unless a flag (or a `chaos_*` key in the config file) arms it;
/// `POST /v1/admin/chaos` can re-arm at runtime either way.
fn chaos_from_args(args: &Args) -> enova::chaos::ChaosConfig {
    let d = enova::chaos::ChaosConfig::default();
    enova::chaos::ChaosConfig {
        seed: args.get_usize("chaos-seed", d.seed as usize) as u64,
        error_rate: args.get_f64("chaos-error-rate", d.error_rate),
        latency_rate: args.get_f64("chaos-latency-rate", d.latency_rate),
        latency_ms: args.get_f64("chaos-latency-ms", d.latency_ms),
        latency_sigma: args.get_f64("chaos-latency-sigma", d.latency_sigma),
        tail_ratio: args.get_f64("chaos-tail-ratio", d.tail_ratio),
        tail_xi: args.get_f64("chaos-tail-xi", d.tail_xi),
        tail_scale_ms: args.get_f64("chaos-tail-scale-ms", d.tail_scale_ms),
        max_delay_ms: args.get_f64("chaos-max-delay-ms", d.max_delay_ms),
        sse_abort_rate: args.get_f64("chaos-sse-abort-rate", d.sse_abort_rate),
        degrade_period_s: args.get_f64("chaos-degrade-period-s", d.degrade_period_s),
        degrade_duty: args.get_f64("chaos-degrade-duty", d.degrade_duty),
        degrade_factor: args.get_f64("chaos-degrade-factor", d.degrade_factor),
    }
}

/// The coordinator's per-node circuit-breaker knobs (`--breaker-*`).
fn breaker_from_args(args: &Args) -> enova::cluster::pool::BreakerConfig {
    use std::time::Duration;
    let d = enova::cluster::pool::BreakerConfig::default();
    enova::cluster::pool::BreakerConfig {
        enabled: args.get_usize("breaker-window", d.window) > 0,
        window: args.get_usize("breaker-window", d.window).max(1),
        min_samples: args.get_usize("breaker-min-samples", d.min_samples).max(1),
        error_threshold: args.get_f64("breaker-error-threshold", d.error_threshold),
        latency_threshold: Duration::from_millis(args.get_usize(
            "breaker-latency-ms",
            d.latency_threshold.as_millis() as usize,
        ) as u64),
        cooldown: Duration::from_millis(
            args.get_usize("breaker-cooldown-ms", d.cooldown.as_millis() as usize) as u64,
        ),
        half_open_probes: args.get_usize("breaker-probes", d.half_open_probes).max(1),
    }
}

/// `enova serve-http`: the OpenAI-compatible serving gateway. `--engine
/// auto` (default) uses the compiled LM when artifacts exist and falls
/// back to the deterministic sim engine otherwise. With `--autoscale`,
/// the closed-loop supervisor hot-adds / retires replicas from the
/// performance detector's decisions; with `--reconfig` it also re-derives
/// `max_num_seqs`/`gpu_memory` from the live monitoring window (§IV-A)
/// and applies the verdict to running replicas. `--warm-pool N` keeps N
/// standby replicas pre-initialized so scale-ups skip engine init.
///
/// `--cluster` turns this process into the *cluster coordinator* instead:
/// no local engines — it owns ingress, heartbeats the registered `enova
/// node` fleet, and turns the same supervisor flags into cross-node
/// placement decisions.
///
/// `--trace-sample F --trace-slo-ms N`: the request-tracing knobs shared
/// by the gateway, the node and the coordinator.
///
/// `tenants` is the `[tenants.*]` roster from `--config enova.toml`
/// (empty -> the built-in default roster).
fn serve_http(args: &Args, tenants: &[enova::gateway::admission::TenantSpec]) -> anyhow::Result<()> {
    use enova::gateway::supervisor::{ForecastPolicy, ReconfigPolicy, SupervisorConfig};
    use enova::gateway::{Gateway, GatewayConfig};
    use std::time::Duration;

    if args.flag("cluster") {
        return serve_cluster(args, tenants);
    }

    let replicas = args.get_usize("replicas", 2).max(1);
    let max_tokens = args.get_usize("max-tokens", 64);
    let (spawner, engine_kind) = spawner_from_args(args)?;

    let autoscale = args.flag("autoscale");
    let reconfig = args.flag("reconfig");
    let forecast = args.flag("forecast");
    let scale_interval_ms = args.get_usize("scale-interval-ms", 1000).max(1);
    let forecast_policy = forecast.then(|| ForecastPolicy {
        horizon_steps: (args.get_usize("forecast-horizon-ms", 30_000) / scale_interval_ms).max(1),
        season_steps: args.get_usize("forecast-season-ms", 0) / scale_interval_ms,
        err_budget: args.get_f64("forecast-err-budget", 1.0),
        replica_capacity_rps: args.get_f64("forecast-capacity", 0.0),
        headroom: args.get_f64("forecast-headroom", 0.15),
        min_warm: args.get_usize("forecast-min-warm", 1),
        trough_scale_down: args.flag("trough-scale-down"),
    });
    let reconfig_policy = reconfig.then(|| ReconfigPolicy {
        interval: Duration::from_millis(args.get_usize("reconfig-interval-ms", 10_000) as u64),
        cooldown: Duration::from_millis(args.get_usize("reconfig-cooldown-ms", 60_000) as u64),
        deadband: args.get_f64("reconfig-deadband", 0.25),
        min_max_num_seqs: args.get_usize("reconfig-min-seqs", 1).max(1),
        max_max_num_seqs: args.get_usize("reconfig-max-seqs", 256),
        window: args.get_usize("reconfig-window", 120),
        ..ReconfigPolicy::default()
    });
    let supervisor = (autoscale || reconfig || forecast).then(|| SupervisorConfig {
        sample_interval: Duration::from_millis(scale_interval_ms as u64),
        calib_samples: args.get_usize("calib-samples", 30),
        patience: args.get_usize("patience", 3),
        cooldown: Duration::from_millis(args.get_usize("cooldown-ms", 30_000) as u64),
        min_replicas: args.get_usize("min-replicas", 1).max(1),
        max_replicas: args.get_usize("max-replicas", replicas.max(4)),
        queue_wait_budget: Duration::from_millis(
            args.get_usize("queue-wait-budget-ms", 500) as u64,
        ),
        detector_scaling: autoscale,
        reconfig: reconfig_policy,
        forecast: forecast_policy,
    });

    let port = args.get_usize("port", 8080);
    anyhow::ensure!(port <= u16::MAX as usize, "--port must be 0..=65535 (got {port})");
    let cfg = GatewayConfig {
        host: args.get_or("host", "127.0.0.1").to_string(),
        port: port as u16,
        max_tokens_default: max_tokens,
        max_pending: args.get_usize("max-pending", 256),
        rate_limit: args.get_f64("rate", 0.0),
        rate_burst: args.get_usize("burst", 64),
        http_workers: args.get_usize("http-workers", 64),
        queue_budget: Duration::from_millis(args.get_usize("queue-budget-ms", 0) as u64),
        warm_pool: args.get_usize("warm-pool", 0),
        ingress: ingress_from_args(args)?,
        trace: trace_settings_from_args(args),
        tenants: tenants.to_vec(),
        chaos: chaos_from_args(args),
        legacy_api: legacy_api_from_args(args)?,
        ..GatewayConfig::default()
    };
    if cfg.chaos.armed() {
        println!(
            "  CHAOS ARMED (seed {}): seeded fault injection is live on this gateway",
            cfg.chaos.seed
        );
    }
    let warm_pool = cfg.warm_pool;
    let gw = Gateway::start_scalable(cfg, spawner, replicas, supervisor)?;
    println!(
        "enova gateway: {replicas}x {engine_kind} replica(s) on http://{} \
         (autoscale: {}, reconfig: {}, forecast: {}, warm pool: {warm_pool})",
        gw.addr,
        if autoscale { "on" } else { "off" },
        if reconfig { "on" } else { "off" },
        if forecast { "on" } else { "off" },
    );
    println!("  try: curl -s http://{}/healthz", gw.addr);
    gw.serve_forever();
    Ok(())
}

/// `enova serve-http --cluster`: the coordinator of the distributed
/// serving plane. Owns ingress (same OpenAI surface, node-aware routing
/// with retry-on-node-death), heartbeats the registered node fleet, and
/// runs the supervisor cluster-wide — scale decisions become placements
/// (`/metrics` exports `enova_cluster_*`).
fn serve_cluster(args: &Args, tenants: &[enova::gateway::admission::TenantSpec]) -> anyhow::Result<()> {
    use enova::cluster::coordinator::{ClusterPolicy, Coordinator, CoordinatorConfig};
    use enova::gateway::supervisor::ForecastPolicy;
    use std::time::Duration;

    let autoscale = args.flag("autoscale");
    let forecast = args.flag("forecast");
    anyhow::ensure!(
        !args.flag("reconfig"),
        "--reconfig is a single-node loop; the coordinator does not reconfigure engines (yet)"
    );
    let scale_interval_ms = args.get_usize("scale-interval-ms", 1000).max(1);
    let forecast_policy = forecast.then(|| ForecastPolicy {
        horizon_steps: (args.get_usize("forecast-horizon-ms", 30_000) / scale_interval_ms).max(1),
        season_steps: args.get_usize("forecast-season-ms", 0) / scale_interval_ms,
        err_budget: args.get_f64("forecast-err-budget", 1.0),
        replica_capacity_rps: args.get_f64("forecast-capacity", 0.0),
        headroom: args.get_f64("forecast-headroom", 0.15),
        min_warm: args.get_usize("forecast-min-warm", 1),
        trough_scale_down: args.flag("trough-scale-down"),
    });
    let port = args.get_usize("port", 8080);
    anyhow::ensure!(port <= u16::MAX as usize, "--port must be 0..=65535 (got {port})");
    let cfg = CoordinatorConfig {
        host: args.get_or("host", "127.0.0.1").to_string(),
        port: port as u16,
        http_workers: args.get_usize("http-workers", 64),
        max_pending: args.get_usize("max-pending", 1024),
        rate_limit: args.get_f64("rate", 0.0),
        rate_burst: args.get_usize("burst", 64),
        heartbeat_interval: Duration::from_millis(args.get_usize("heartbeat-ms", 500) as u64),
        node_timeout_beats: args.get_usize("node-timeout-beats", 3).max(1) as u32,
        dispatch_attempts: args.get_usize("dispatch-attempts", 3).max(1),
        policy: ClusterPolicy {
            sample_interval: Duration::from_millis(scale_interval_ms as u64),
            calib_samples: args.get_usize("calib-samples", 30),
            patience: args.get_usize("patience", 3),
            cooldown: Duration::from_millis(args.get_usize("cooldown-ms", 30_000) as u64),
            min_replicas: args.get_usize("min-replicas", 1).max(1),
            max_replicas: args.get_usize("max-replicas", 8),
            queue_wait_budget: Duration::from_millis(
                args.get_usize("queue-wait-budget-ms", 500) as u64,
            ),
            detector_scaling: autoscale,
            forecast: forecast_policy,
            defrag: args.flag("defrag"),
            ..ClusterPolicy::default()
        },
        ingress: ingress_from_args(args)?,
        trace: trace_settings_from_args(args),
        tenants: tenants.to_vec(),
        breaker: breaker_from_args(args),
        legacy_api: legacy_api_from_args(args)?,
        snapshot_interval: Duration::from_millis(
            args.get_usize("snapshot-interval-ms", 3000) as u64
        ),
        ..CoordinatorConfig::default()
    };
    let coordinator = Coordinator::start(cfg)?;
    println!(
        "enova cluster coordinator on http://{} (autoscale: {}, forecast: {})",
        coordinator.addr,
        if autoscale { "on" } else { "off" },
        if forecast { "on" } else { "off" },
    );
    println!("  nodes join with: enova node --coordinator {}", coordinator.addr);
    coordinator.serve_forever();
    Ok(())
}

/// `enova node`: one serving node of the distributed plane — the full
/// gateway (engines, warm pool, `/metrics`) in node mode, registering
/// with a coordinator and executing its placement decisions.
fn node_cmd(args: &Args, tenants: &[enova::gateway::admission::TenantSpec]) -> anyhow::Result<()> {
    use enova::cluster::node::{NodeConfig, NodeServer};
    use enova::cluster::NodeIdentity;
    use enova::gateway::GatewayConfig;
    use std::time::Duration;

    let replicas = args.get_usize("replicas", 1).max(1);
    let (spawner, engine_kind) = spawner_from_args(args)?;
    let port = args.get_usize("port", 8081);
    anyhow::ensure!(port <= u16::MAX as usize, "--port must be 0..=65535 (got {port})");

    let gpu_memory_total = args.get_f64("gpu-memory", 24.0);
    let replica_gpu_memory = args.get_f64("replica-gpu-memory", 8.0);
    anyhow::ensure!(
        gpu_memory_total > 0.0 && replica_gpu_memory > 0.0,
        "--gpu-memory and --replica-gpu-memory must be positive"
    );
    let fit = (gpu_memory_total / replica_gpu_memory).floor() as usize;
    let identity = NodeIdentity {
        node_id: args.get_or("node-id", &format!("node-{port}")).to_string(),
        gpu_memory_total,
        replica_gpu_memory,
        max_replicas: args.get_usize("node-max-replicas", fit.max(1)),
        replica_capacity_rps: args.get_f64("capacity-rps", 0.0),
    };
    let cfg = NodeConfig {
        gateway: GatewayConfig {
            host: args.get_or("host", "127.0.0.1").to_string(),
            port: port as u16,
            max_tokens_default: args.get_usize("max-tokens", 64),
            max_pending: args.get_usize("max-pending", 256),
            rate_limit: args.get_f64("rate", 0.0),
            rate_burst: args.get_usize("burst", 64),
            http_workers: args.get_usize("http-workers", 64),
            queue_budget: Duration::from_millis(args.get_usize("queue-budget-ms", 0) as u64),
            warm_pool: args.get_usize("warm-pool", 0),
            ingress: ingress_from_args(args)?,
            trace: trace_settings_from_args(args),
            tenants: tenants.to_vec(),
            chaos: chaos_from_args(args),
            legacy_api: legacy_api_from_args(args)?,
            ..GatewayConfig::default()
        },
        identity,
        initial_replicas: replicas,
        coordinator: args.get("coordinator").map(str::to_string),
        announce_interval: Duration::from_millis(args.get_usize("announce-ms", 1000).max(50) as u64),
        advertise_addr: args.get("advertise").map(str::to_string),
    };
    let node = NodeServer::start(cfg, spawner)?;
    println!(
        "enova node {} on http://{} ({replicas}x {engine_kind} replica(s), coordinator: {})",
        node.node_id(),
        node.addr_string(),
        args.get_or("coordinator", "none"),
    );
    node.serve_forever();
    Ok(())
}

/// `enova loadgen`: drive a running gateway and report. Without
/// `--scenario` this is the classic closed loop; with one it replays a
/// named open-loop arrival pattern (the scenario engine). With `--report
/// FILE` the full report is written as JSON (the CI smoke/bench jobs'
/// artifact); with `--strict` any transport error or non-2xx response
/// makes the command fail.
///
/// `--adversarial PERSONAS` (e.g. `slow-loris,sse-disconnect`, or `all`)
/// additionally runs seeded misbehaving clients *alongside* the
/// well-formed load for the same `--duration-s`, seeded by
/// `--chaos-seed`; their outcomes land under `"adversarial"` in the
/// report. `--strict` still grades only the well-formed traffic — the
/// point is to prove hostile clients cannot degrade it.
fn loadgen_cmd(args: &Args) -> anyhow::Result<()> {
    use enova::gateway::loadgen::{self, ScenarioConfig, ScenarioKind};
    use std::time::Duration;
    let addr = args.get_or("addr", "127.0.0.1:8080").to_string();
    let adversarial_handle = match args.get("adversarial") {
        Some(list) => {
            let kinds =
                loadgen::parse_adversarial_list(if list == "all" { "" } else { list })?;
            let cfg = loadgen::AdversarialConfig {
                kinds,
                clients: args.get_usize("adversarial-clients", 4).max(1),
                duration: Duration::from_secs_f64(args.get_f64("duration-s", 10.0).max(0.1)),
                seed: args.get_usize("chaos-seed", 42) as u64,
                max_tokens: args.get_usize("max-tokens", 8),
            };
            println!(
                "adversarial personas {:?} with {} clients for {:.1}s (seed {})",
                cfg.kinds.iter().map(|k| k.name()).collect::<Vec<_>>(),
                cfg.clients,
                cfg.duration.as_secs_f64(),
                cfg.seed
            );
            let addr = addr.clone();
            Some(std::thread::spawn(move || loadgen::run_adversarial(&addr, &cfg)))
        }
        None => None,
    };
    let report = match args.get("scenario") {
        Some(name) => {
            let kind = ScenarioKind::parse(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario {name:?}; expected one of steady, diurnal, spike, ramp, \
                     mixture"
                )
            })?;
            let cfg = ScenarioConfig {
                kind,
                duration: Duration::from_secs_f64(args.get_f64("duration-s", 10.0).max(0.1)),
                base_rps: args.get_f64("base-rps", 2.0),
                peak_rps: args.get_f64("peak-rps", 8.0),
                period: Duration::from_secs_f64(args.get_f64("period-s", 0.0).max(0.0)),
                spike_start: args.get_f64("spike-start", 0.5),
                spike_len: args.get_f64("spike-len", 0.2),
                seed: args.get_usize("seed", 42) as u64,
                workers: args.get_usize("workers", 32).max(1),
                max_tokens: args.get_usize("max-tokens", 8),
                ..ScenarioConfig::default()
            };
            println!(
                "scenario {} for {:.1}s: base {} rps, peak {} rps, seed {}",
                kind.name(),
                cfg.duration.as_secs_f64(),
                cfg.base_rps,
                cfg.peak_rps,
                cfg.seed
            );
            loadgen::run_scenario(&addr, &cfg)
        }
        None => {
            let cfg = loadgen::LoadgenConfig {
                concurrency: args.get_usize("concurrency", 8).max(1),
                requests_per_worker: args.get_usize("requests", 4).max(1),
                max_tokens: args.get_usize("max-tokens", 8),
                ..Default::default()
            };
            loadgen::run(&addr, &cfg)
        }
    };
    println!("{}", report.summary());
    let adversarial_report = adversarial_handle.map(|h| h.join().unwrap_or_default());
    if let Some(adv) = &adversarial_report {
        println!("{}", adv.summary());
    }
    if let Some(path) = args.get("report") {
        let mut out = report.to_json();
        if let (enova::util::json::Json::Obj(m), Some(adv)) = (&mut out, &adversarial_report) {
            m.insert("adversarial".to_string(), adv.to_json());
        }
        std::fs::write(path, out.to_string_pretty())?;
        println!("report written to {path}");
    }
    if args.flag("strict") {
        let non_2xx: usize = report
            .status_counts
            .iter()
            .filter(|&(&code, _)| !(200..300).contains(&code))
            .map(|(_, &n)| n)
            .sum();
        anyhow::ensure!(
            report.errors == 0 && non_2xx == 0,
            "strict loadgen failed: {} transport errors, {} non-2xx responses ({:?})",
            report.errors,
            non_2xx,
            report.status_counts
        );
        // graded per-tenant SLOs (mixture scenarios): every tenant with a
        // p95 budget must be inside it
        let violations = report.slo_violations();
        anyhow::ensure!(
            violations.is_empty(),
            "strict loadgen failed per-tenant SLO grading:\n  {}",
            violations.join("\n  ")
        );
    }
    Ok(())
}

/// `enova bench-gateway`: the CI bench-trend driver. Boots an in-process
/// sim-engine gateway with the forecast-aware supervisor per scenario,
/// replays the scenario open-loop, and writes one JSON artifact with
/// p50/p95 latency, shed counts and the proactive/reactive scale-event
/// split. With `--baseline FILE` present on disk, fails when any
/// scenario's p95 regresses more than `--regression-pct` (default 20%).
fn bench_gateway(args: &Args) -> anyhow::Result<()> {
    use enova::engine::sim::{SimEngine, SimEngineConfig};
    use enova::engine::StreamEngine;
    use enova::gateway::loadgen::{self, ScenarioConfig, ScenarioKind};
    use enova::gateway::supervisor::{ForecastPolicy, SupervisorConfig};
    use enova::gateway::{EngineSpawner, Gateway, GatewayConfig};
    use enova::util::json::{num, obj, s, Json};
    use std::sync::Arc;
    use std::time::Duration;

    let duration = args.get_f64("duration-s", 6.0).max(0.5);
    let regression_pct = args.get_f64("regression-pct", 20.0).max(0.0);
    let report_path = args.get_or("report", "BENCH_gateway.json").to_string();
    let baseline_path = args.get_or("baseline", "").to_string();
    let mut kinds = Vec::new();
    for name in args.get_or("scenarios", "steady,spike,diurnal").split(',') {
        let name = name.trim();
        if name.is_empty() {
            continue;
        }
        kinds.push(
            ScenarioKind::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown scenario {name:?}"))?,
        );
    }
    anyhow::ensure!(!kinds.is_empty(), "--scenarios must name at least one scenario");

    let mut rows: Vec<Json> = Vec::new();
    let mut proactive_total = 0u64;
    let mut reactive_total = 0u64;
    for kind in kinds {
        let spawner: EngineSpawner = Arc::new(|_id| {
            Ok(Box::new(SimEngine::new(SimEngineConfig {
                max_num_seqs: 4,
                max_tokens: 64,
                step_delay: Duration::from_millis(2),
            })) as Box<dyn StreamEngine>)
        });
        let sup = SupervisorConfig {
            sample_interval: Duration::from_millis(100),
            calib_samples: 20,
            patience: 2,
            cooldown: Duration::from_millis(1000),
            min_replicas: 1,
            max_replicas: 3,
            queue_wait_budget: Duration::from_millis(500),
            detector_scaling: true,
            reconfig: None,
            forecast: Some(ForecastPolicy {
                horizon_steps: 10,
                err_budget: 1.5,
                replica_capacity_rps: 40.0,
                ..ForecastPolicy::default()
            }),
        };
        let gw = Gateway::start_scalable(
            GatewayConfig {
                warm_pool: 1,
                monitor_interval: Duration::from_millis(50),
                max_pending: 1024,
                ..GatewayConfig::default()
            },
            spawner,
            1,
            Some(sup),
        )?;
        let scn = ScenarioConfig {
            kind,
            duration: Duration::from_secs_f64(duration),
            base_rps: 4.0,
            peak_rps: 24.0,
            seed: 11,
            workers: 32,
            max_tokens: 8,
            ..ScenarioConfig::default()
        };
        let report = loadgen::run_scenario(&gw.addr_string(), &scn);
        let snap = gw.supervisor_snapshot();
        let p95_queue_wait = gw.queue_wait_quantile(0.95);
        gw.shutdown();
        println!("{}: {}", kind.name(), report.summary());
        proactive_total += snap.proactive_events;
        reactive_total += snap.reactive_events;
        rows.push(obj([
            ("scenario", s(kind.name())),
            ("requests", num(report.requests as f64)),
            ("errors", num(report.errors as f64)),
            ("shed_503", num(report.count(503) as f64)),
            ("p50_ms", num(report.p50_ms)),
            ("p95_ms", num(report.p95_ms)),
            ("p99_ms", num(report.p99_ms)),
            ("p95_queue_wait_s", num(p95_queue_wait)),
            ("proactive_scale_events", num(snap.proactive_events as f64)),
            ("reactive_scale_events", num(snap.reactive_events as f64)),
        ]));
    }
    // the distributed plane rides the same perf trajectory: a 2-node
    // in-process cluster under the spike scenario, same report columns
    if !args.flag("no-cluster-bench") {
        rows.push(bench_cluster_row(duration)?);
    }
    // ingress max-throughput: requests-to-saturation on fresh connections,
    // reactor and thread-per-connection measured in the same run so the
    // comparison is apples-to-apples on this machine
    if !args.flag("no-saturation-bench") {
        let sat_secs = args.get_f64("saturation-s", 3.0).max(0.5);
        let reactor = bench_saturation_row(enova::gateway::IngressMode::Reactor, sat_secs)?;
        let threaded = bench_saturation_row(enova::gateway::IngressMode::Threaded, sat_secs)?;
        let r_rps = reactor.get("max_rps").and_then(Json::as_f64).unwrap_or(0.0);
        let t_rps = threaded.get("max_rps").and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "saturation (same run): reactor {r_rps:.0} rps vs threaded {t_rps:.0} rps ({:+.1}%)",
            if t_rps > 0.0 { (r_rps / t_rps - 1.0) * 100.0 } else { 0.0 }
        );
        rows.push(reactor);
        rows.push(threaded);
    }
    let out = obj([
        ("bench", s("gateway_scenarios")),
        ("duration_s", num(duration)),
        ("scenarios", Json::Arr(rows.clone())),
        ("proactive_scale_events_total", num(proactive_total as f64)),
        ("reactive_scale_events_total", num(reactive_total as f64)),
    ]);
    std::fs::write(&report_path, out.to_string_pretty())?;
    println!("bench report written to {report_path}");

    if baseline_path.is_empty() || !std::path::Path::new(&baseline_path).exists() {
        println!("no committed baseline; regression gate skipped");
        return Ok(());
    }
    let baseline = Json::parse(&std::fs::read_to_string(&baseline_path)?)
        .map_err(|e| anyhow::anyhow!("bad baseline JSON at {baseline_path}: {e}"))?;
    let empty: Vec<Json> = Vec::new();
    let base_rows = baseline
        .get("scenarios")
        .and_then(Json::as_arr)
        .unwrap_or(&empty);
    for row in &rows {
        let name = row.get("scenario").and_then(Json::as_str).unwrap_or("");
        let base = base_rows
            .iter()
            .find(|b| b.get("scenario").and_then(Json::as_str) == Some(name));
        let Some(base) = base else { continue };
        let new_p95 = row.get("p95_ms").and_then(Json::as_f64).unwrap_or(0.0);
        if let Some(old_p95) = base.get("p95_ms").and_then(Json::as_f64) {
            if old_p95 > 0.0 && new_p95 > old_p95 * (1.0 + regression_pct / 100.0) {
                anyhow::bail!(
                    "p95 regression on {name}: {new_p95:.1}ms vs baseline {old_p95:.1}ms \
                     (> {regression_pct:.0}% worse)"
                );
            }
            println!("{name}: p95 {new_p95:.1}ms vs baseline {old_p95:.1}ms — ok");
        }
        // throughput floor on the saturation rows: max attack rate must
        // not drop by more than the regression budget
        if let (Some(new_rps), Some(old_rps)) = (
            row.get("max_rps").and_then(Json::as_f64),
            base.get("max_rps").and_then(Json::as_f64),
        ) {
            if old_rps > 0.0 && new_rps < old_rps * (1.0 - regression_pct / 100.0) {
                anyhow::bail!(
                    "throughput regression on {name}: {new_rps:.0} rps vs baseline \
                     {old_rps:.0} rps (> {regression_pct:.0}% worse)"
                );
            }
            println!("{name}: {new_rps:.0} rps vs baseline {old_rps:.0} rps — ok");
        }
    }
    Ok(())
}

/// The ingress max-throughput scenario of `bench-gateway`: a closed loop
/// of fresh (`Connection: close`) requests against a near-free sim
/// engine, so connection setup + parse + dispatch — the part the ingress
/// mode changes — dominates the cost. Reports the attack rate the
/// gateway sustained as `max_rps`, which the regression gate checks as a
/// floor, alongside the usual latency columns. Run once per
/// [`enova::gateway::IngressMode`] so the two rows are measured
/// back-to-back in the same process on the same machine.
fn bench_saturation_row(
    mode: enova::gateway::IngressMode,
    secs: f64,
) -> anyhow::Result<enova::util::json::Json> {
    use enova::engine::sim::{SimEngine, SimEngineConfig};
    use enova::engine::StreamEngine;
    use enova::gateway::{loadgen, EngineSpawner, Gateway, GatewayConfig, IngressMode};
    use enova::util::json::{num, obj, s};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let spawner: EngineSpawner = Arc::new(|_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 64,
            max_tokens: 16,
            step_delay: Duration::ZERO,
        })) as Box<dyn StreamEngine>)
    });
    let gw = Gateway::start_scalable(
        GatewayConfig {
            ingress: mode,
            max_pending: 4096,
            ..GatewayConfig::default()
        },
        spawner,
        2,
        None,
    )?;
    let addr = gw.addr_string();

    const WORKERS: usize = 32;
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs_f64(secs);
    let mut handles = Vec::new();
    for w in 0..WORKERS {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let body = format!("{{\"prompt\":\"saturation {w}\",\"max_tokens\":1}}");
            let mut lat_ms: Vec<f64> = Vec::new();
            let (mut shed, mut errors) = (0u64, 0u64);
            while Instant::now() < deadline {
                let t = Instant::now();
                match loadgen::request(
                    &addr,
                    "POST",
                    "/v1/completions",
                    Some(&body),
                    Duration::from_secs(10),
                ) {
                    Ok(resp) if resp.status == 200 => {
                        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    Ok(resp) if resp.status == 503 => shed += 1,
                    Ok(_) | Err(_) => errors += 1,
                }
            }
            (lat_ms, shed, errors)
        }));
    }
    let mut lat_ms: Vec<f64> = Vec::new();
    let (mut shed, mut errors) = (0u64, 0u64);
    for h in handles {
        if let Ok((worker_lat, worker_shed, worker_errors)) = h.join() {
            lat_ms.extend(worker_lat);
            shed += worker_shed;
            errors += worker_errors;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-6);
    gw.shutdown();

    lat_ms.sort_by(f64::total_cmp);
    let pct = |q: f64| -> f64 {
        if lat_ms.is_empty() {
            0.0
        } else {
            lat_ms[((lat_ms.len() - 1) as f64 * q).round() as usize]
        }
    };
    let ok = lat_ms.len() as u64;
    let name = match mode {
        IngressMode::Reactor => "saturation_reactor",
        IngressMode::Threaded => "saturation_threaded",
    };
    println!(
        "{name}: {ok} ok, {shed} shed, {errors} errors in {elapsed:.2}s — {:.0} rps, \
         p95 {:.1}ms",
        ok as f64 / elapsed,
        pct(0.95),
    );
    Ok(obj([
        ("scenario", s(name)),
        ("requests", num((ok + shed + errors) as f64)),
        ("errors", num(errors as f64)),
        ("shed_503", num(shed as f64)),
        ("p50_ms", num(pct(0.50))),
        ("p95_ms", num(pct(0.95))),
        ("p99_ms", num(pct(0.99))),
        ("max_rps", num(ok as f64 / elapsed)),
    ]))
}

/// The 2-node cluster scenario of `bench-gateway`: an in-process
/// coordinator + two sim-engine nodes under the spike scenario, driven
/// through the coordinator's ingress — so the distributed plane is on the
/// same p95 regression trajectory as the single-gateway scenarios.
fn bench_cluster_row(duration: f64) -> anyhow::Result<enova::util::json::Json> {
    use enova::cluster::coordinator::{ClusterPolicy, Coordinator, CoordinatorConfig};
    use enova::cluster::node::{NodeConfig, NodeServer};
    use enova::cluster::NodeIdentity;
    use enova::engine::sim::{SimEngine, SimEngineConfig};
    use enova::engine::StreamEngine;
    use enova::gateway::loadgen::{self, ScenarioConfig, ScenarioKind};
    use enova::gateway::supervisor::ForecastPolicy;
    use enova::gateway::{EngineSpawner, GatewayConfig};
    use enova::util::json::{num, obj, s, Json};
    use std::sync::Arc;
    use std::time::Duration;

    let sim_spawner = || -> EngineSpawner {
        Arc::new(|_id| {
            Ok(Box::new(SimEngine::new(SimEngineConfig {
                max_num_seqs: 4,
                max_tokens: 64,
                step_delay: Duration::from_millis(2),
            })) as Box<dyn StreamEngine>)
        })
    };
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(100),
        policy: ClusterPolicy {
            sample_interval: Duration::from_millis(100),
            cooldown: Duration::from_millis(1000),
            min_replicas: 2,
            max_replicas: 4,
            detector_scaling: false,
            forecast: Some(ForecastPolicy {
                horizon_steps: 10,
                err_budget: 1.5,
                replica_capacity_rps: 40.0,
                ..ForecastPolicy::default()
            }),
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })?;
    let node_cfg = |id: &str| NodeConfig {
        gateway: GatewayConfig {
            max_pending: 1024,
            monitor_interval: Duration::from_millis(50),
            warm_pool: 1,
            ..GatewayConfig::default()
        },
        identity: NodeIdentity {
            node_id: id.to_string(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 2,
            replica_capacity_rps: 40.0,
        },
        initial_replicas: 1,
        coordinator: Some(coordinator.addr_string()),
        announce_interval: Duration::from_millis(200),
        advertise_addr: None,
    };
    let node_a = NodeServer::start(node_cfg("bench-node-a"), sim_spawner())?;
    let node_b = NodeServer::start(node_cfg("bench-node-b"), sim_spawner())?;
    anyhow::ensure!(
        coordinator.wait_for_nodes(2, Duration::from_secs(10)),
        "bench cluster never reached 2 serving nodes"
    );
    let scn = ScenarioConfig {
        kind: ScenarioKind::Spike,
        duration: Duration::from_secs_f64(duration),
        base_rps: 4.0,
        peak_rps: 24.0,
        seed: 11,
        workers: 32,
        max_tokens: 8,
        ..ScenarioConfig::default()
    };
    let report = loadgen::run_scenario(&coordinator.addr_string(), &scn);
    let placements = coordinator.placements().len();
    let nodes = coordinator.healthy_nodes();
    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
    println!("cluster_spike_2node: {}", report.summary());
    let row: Json = obj([
        ("scenario", s("cluster_spike_2node")),
        ("nodes", num(nodes as f64)),
        ("requests", num(report.requests as f64)),
        ("errors", num(report.errors as f64)),
        ("shed_503", num(report.count(503) as f64)),
        ("p50_ms", num(report.p50_ms)),
        ("p95_ms", num(report.p95_ms)),
        ("p99_ms", num(report.p99_ms)),
        ("placements", num(placements as f64)),
    ]);
    Ok(row)
}

fn recommend(args: &Args) -> anyhow::Result<()> {
    let gpu = enova::simulator::gpu::by_name(args.get_or("gpu", "A100-80G"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let model = enova::simulator::modelcard::by_name(args.get_or("model", "L-7B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let (cfg, n_limit) = enova::bench::scenarios::enova_recommend(gpu, model, 1);
    println!("ENOVA recommendation for {} on {}:", model.name, gpu.name);
    println!("  max_num_seqs  = {}", cfg.max_num_seqs);
    println!("  max_tokens    = {}", cfg.max_tokens);
    println!("  gpu_memory    = {:.2}", cfg.gpu_memory);
    println!("  parallel_size = {}", cfg.parallel_size);
    println!("  est. n_limit  = {n_limit:.2} req/s per replica");
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn detect(_args: &Args) -> anyhow::Result<()> {
    anyhow::bail!("`detect` runs the compiled VAE; rebuild with the `xla-runtime` feature")
}

#[cfg(feature = "xla-runtime")]
fn detect(_args: &Args) -> anyhow::Result<()> {
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    let ds = enova::detect::dataset::DetectionDataset::load(&m.detection_dataset)?;
    let rt = enova::runtime::PjRt::cpu()?;
    let vae = enova::runtime::vae::VaeRuntime::load(rt, &m)?;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for i in (0..ds.train_rows()).step_by(4) {
        rows.extend_from_slice(ds.train_row(i));
        labels.push(ds.train_labels[i]);
    }
    let det = enova::detect::EnovaDetector::calibrate_semisupervised(vae, &rows, &labels)?;
    let scores: Vec<f64> = det.score(&ds.test)?.into_iter().map(|s| s.recon_err).collect();
    let prf = enova::detect::eval::prf_at(&ds.test_labels, &scores, det.threshold);
    println!(
        "test split: precision {:.3} recall {:.3} f1 {:.3} (threshold {:.2})",
        prf.precision, prf.recall, prf.f1, det.threshold
    );
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    use enova::simulator::replica::{Replica, ServiceConfig};
    use enova::workload::arrivals::{poisson_stream, RateProfile};
    use enova::workload::corpus::{CorpusMix, ALL_FAMILIES};
    let gpu = enova::simulator::gpu::by_name(args.get_or("gpu", "A100-80G"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let model = enova::simulator::modelcard::by_name(args.get_or("model", "L-7B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let rps = args.get_f64("rps", 5.0);
    let secs = args.get_f64("seconds", 300.0);
    let cfg = ServiceConfig {
        max_num_seqs: args.get_usize("max-num-seqs", 32),
        gpu_memory: args.get_f64("gpu-memory", 0.9),
        max_tokens: args.get_usize("max-tokens", 512),
        parallel_size: args.get_usize("parallel-size", 1),
    };
    let mut rng = enova::util::rng::Pcg64::new(3);
    let arrivals = poisson_stream(
        &RateProfile::constant(rps),
        &CorpusMix::uniform(&ALL_FAMILIES),
        secs,
        &mut rng,
    );
    let issued = arrivals.len();
    let res = Replica::new(gpu, model, cfg).simulate(arrivals, secs + 120.0);
    println!(
        "{} on {} @ {rps} rps for {secs}s: finished {}/{issued}, timed out {}, \
         {:.0} tok/gpu/s, mean norm latency {:.3}s/tok, p99 latency {:.1}s",
        model.name, gpu.name, res.finished.len(), res.timed_out,
        res.throughput_per_gpu(), res.mean_normalized_latency(), res.p99_latency()
    );
    Ok(())
}
