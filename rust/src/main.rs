//! ENOVA leader binary: deployment, monitoring and autoscaling for
//! serverless LLM serving. Subcommands exercise the public API; the
//! examples/benches are the full experiment drivers.

use enova::util::cli::Args;

const USAGE: &str = "\
enova — autoscaling towards cost-effective and stable serverless LLM serving

USAGE: enova <COMMAND> [OPTIONS]

COMMANDS:
  serve       serve prompts on the compiled tiny LM (options: --prompts N --max-tokens N)
  serve-http  OpenAI-compatible HTTP gateway (--port 8080 --replicas 2 --engine auto|lm|sim
              --max-num-seqs N --max-tokens N --max-pending N --rate RPS --burst N
              --http-workers N --sim-delay-ms N --host ADDR --queue-budget-ms N
              --autoscale [--min-replicas N --max-replicas N --scale-interval-ms N
              --calib-samples N --patience N --cooldown-ms N --queue-wait-budget-ms N])
  recommend   run the service configuration module for --model <name> --gpu <name>
  detect      calibrate + run the performance detector on the trace dataset
  simulate    simulate a replica (--model --gpu --rps --seconds --max-num-seqs)
  info        print artifact manifest summary
";

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env_known(&["verbose", "autoscale"]);
    let cmd = args.subcommand();
    match cmd.as_str() {
        "serve" => serve(&args),
        "serve-http" => serve_http(&args),
        "recommend" => recommend(&args),
        "detect" => detect(&args),
        "simulate" => simulate(&args),
        "info" => info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn info() -> anyhow::Result<()> {
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    println!("artifacts: {}", m.dir.display());
    println!(
        "model: {} params, batch {}, ctx {}, vocab {} ({} / {})",
        m.model.param_count, m.model.batch, m.model.max_seq, m.model.vocab,
        m.model.decode_file, m.model.prefill_file
    );
    println!("vae: {} ({} features)", m.vae.file, m.vae.n_features);
    println!("embed: {} ({}→{})", m.embed.file, m.embed.hash_dim, m.embed.embed_dim);
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use enova::engine::{Engine, EngineConfig};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    let n = args.get_usize("prompts", 8);
    let max_tokens = args.get_usize("max-tokens", 24);
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    let rt = enova::runtime::PjRt::cpu()?;
    let lm = LmRuntime::load(rt, &m, ExecMode::Chained)?;
    let mut engine = Engine::new(
        lm,
        EngineConfig { max_num_seqs: 8, max_tokens, temperature: 0.7 },
        1,
    );
    let mut rng = enova::util::rng::Pcg64::new(2);
    for _ in 0..n {
        let fam = *rng.choice(&enova::workload::corpus::ALL_FAMILIES);
        let item = enova::workload::corpus::sample_item(fam, &mut rng);
        engine.submit(&item.text, max_tokens);
    }
    let t0 = std::time::Instant::now();
    let done = engine.run_to_completion()?;
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    println!(
        "served {} requests / {tokens} tokens in {:.2}s",
        done.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `enova serve-http`: the OpenAI-compatible serving gateway. `--engine
/// auto` (default) uses the compiled LM when artifacts exist and falls
/// back to the deterministic sim engine otherwise. With `--autoscale`,
/// the closed-loop supervisor hot-adds / retires replicas from the
/// performance detector's decisions.
fn serve_http(args: &Args) -> anyhow::Result<()> {
    use enova::engine::sim::{SimEngine, SimEngineConfig};
    use enova::engine::{Engine, EngineConfig, StreamEngine};
    use enova::gateway::supervisor::SupervisorConfig;
    use enova::gateway::{EngineSpawner, Gateway, GatewayConfig};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    use std::sync::Arc;
    use std::time::Duration;

    let replicas = args.get_usize("replicas", 2).max(1);
    let max_num_seqs = args.get_usize("max-num-seqs", 8);
    let max_tokens = args.get_usize("max-tokens", 64);
    let temperature = args.get_f64("temperature", 0.7);
    let sim_delay = Duration::from_millis(args.get_usize("sim-delay-ms", 0) as u64);

    let engine_kind = match args.get_or("engine", "auto") {
        "auto" => {
            if enova::runtime::Manifest::artifacts_exist() {
                "lm"
            } else {
                eprintln!("artifacts not found; serving with the deterministic sim engine");
                "sim"
            }
        }
        "lm" => "lm",
        "sim" => "sim",
        other => anyhow::bail!("--engine must be auto, lm or sim (got {other:?})"),
    };

    // a reusable spawner (not one-shot factories) so the supervisor can
    // hot-add replicas beyond the initial set
    let use_lm = engine_kind == "lm";
    let spawner: EngineSpawner = if use_lm {
        Arc::new(move |id| {
            let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
            let rt = enova::runtime::PjRt::cpu()?;
            let lm = LmRuntime::load(rt, &m, ExecMode::Chained)?;
            let cfg = EngineConfig {
                max_num_seqs,
                max_tokens,
                temperature,
            };
            Ok(Box::new(Engine::new(lm, cfg, 100 + id)) as Box<dyn StreamEngine>)
        })
    } else {
        Arc::new(move |_id| {
            Ok(Box::new(SimEngine::new(SimEngineConfig {
                max_num_seqs,
                max_tokens,
                step_delay: sim_delay,
            })) as Box<dyn StreamEngine>)
        })
    };

    let autoscale = args.flag("autoscale");
    let supervisor = autoscale.then(|| SupervisorConfig {
        sample_interval: Duration::from_millis(args.get_usize("scale-interval-ms", 1000) as u64),
        calib_samples: args.get_usize("calib-samples", 30),
        patience: args.get_usize("patience", 3),
        cooldown: Duration::from_millis(args.get_usize("cooldown-ms", 30_000) as u64),
        min_replicas: args.get_usize("min-replicas", 1).max(1),
        max_replicas: args.get_usize("max-replicas", replicas.max(4)),
        queue_wait_budget: Duration::from_millis(
            args.get_usize("queue-wait-budget-ms", 500) as u64,
        ),
    });

    let port = args.get_usize("port", 8080);
    anyhow::ensure!(port <= u16::MAX as usize, "--port must be 0..=65535 (got {port})");
    let cfg = GatewayConfig {
        host: args.get_or("host", "127.0.0.1").to_string(),
        port: port as u16,
        max_tokens_default: max_tokens,
        max_pending: args.get_usize("max-pending", 256),
        rate_limit: args.get_f64("rate", 0.0),
        rate_burst: args.get_usize("burst", 64),
        http_workers: args.get_usize("http-workers", 64),
        queue_budget: Duration::from_millis(args.get_usize("queue-budget-ms", 0) as u64),
        ..GatewayConfig::default()
    };
    let gw = Gateway::start_scalable(cfg, spawner, replicas, supervisor)?;
    println!(
        "enova gateway: {replicas}x {engine_kind} replica(s) on http://{} (autoscale: {})",
        gw.addr,
        if autoscale { "on" } else { "off" }
    );
    println!("  try: curl -s http://{}/healthz", gw.addr);
    gw.serve_forever();
    Ok(())
}

fn recommend(args: &Args) -> anyhow::Result<()> {
    let gpu = enova::simulator::gpu::by_name(args.get_or("gpu", "A100-80G"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let model = enova::simulator::modelcard::by_name(args.get_or("model", "L-7B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let (cfg, n_limit) = enova::bench::scenarios::enova_recommend(gpu, model, 1);
    println!("ENOVA recommendation for {} on {}:", model.name, gpu.name);
    println!("  max_num_seqs  = {}", cfg.max_num_seqs);
    println!("  max_tokens    = {}", cfg.max_tokens);
    println!("  gpu_memory    = {:.2}", cfg.gpu_memory);
    println!("  parallel_size = {}", cfg.parallel_size);
    println!("  est. n_limit  = {n_limit:.2} req/s per replica");
    Ok(())
}

fn detect(_args: &Args) -> anyhow::Result<()> {
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    let ds = enova::detect::dataset::DetectionDataset::load(&m.detection_dataset)?;
    let rt = enova::runtime::PjRt::cpu()?;
    let vae = enova::runtime::vae::VaeRuntime::load(rt, &m)?;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for i in (0..ds.train_rows()).step_by(4) {
        rows.extend_from_slice(ds.train_row(i));
        labels.push(ds.train_labels[i]);
    }
    let det = enova::detect::EnovaDetector::calibrate_semisupervised(vae, &rows, &labels)?;
    let scores: Vec<f64> = det.score(&ds.test)?.into_iter().map(|s| s.recon_err).collect();
    let prf = enova::detect::eval::prf_at(&ds.test_labels, &scores, det.threshold);
    println!(
        "test split: precision {:.3} recall {:.3} f1 {:.3} (threshold {:.2})",
        prf.precision, prf.recall, prf.f1, det.threshold
    );
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    use enova::simulator::replica::{Replica, ServiceConfig};
    use enova::workload::arrivals::{poisson_stream, RateProfile};
    use enova::workload::corpus::{CorpusMix, ALL_FAMILIES};
    let gpu = enova::simulator::gpu::by_name(args.get_or("gpu", "A100-80G"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let model = enova::simulator::modelcard::by_name(args.get_or("model", "L-7B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let rps = args.get_f64("rps", 5.0);
    let secs = args.get_f64("seconds", 300.0);
    let cfg = ServiceConfig {
        max_num_seqs: args.get_usize("max-num-seqs", 32),
        gpu_memory: args.get_f64("gpu-memory", 0.9),
        max_tokens: args.get_usize("max-tokens", 512),
        parallel_size: args.get_usize("parallel-size", 1),
    };
    let mut rng = enova::util::rng::Pcg64::new(3);
    let arrivals = poisson_stream(
        &RateProfile::constant(rps),
        &CorpusMix::uniform(&ALL_FAMILIES),
        secs,
        &mut rng,
    );
    let issued = arrivals.len();
    let res = Replica::new(gpu, model, cfg).simulate(arrivals, secs + 120.0);
    println!(
        "{} on {} @ {rps} rps for {secs}s: finished {}/{issued}, timed out {}, \
         {:.0} tok/gpu/s, mean norm latency {:.3}s/tok, p99 latency {:.1}s",
        model.name, gpu.name, res.finished.len(), res.timed_out,
        res.throughput_per_gpu(), res.mean_normalized_latency(), res.p99_latency()
    );
    Ok(())
}
