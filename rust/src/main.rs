//! ENOVA leader binary: deployment, monitoring and autoscaling for
//! serverless LLM serving. Subcommands exercise the public API; the
//! examples/benches are the full experiment drivers.

use enova::util::cli::Args;

const USAGE: &str = "\
enova — autoscaling towards cost-effective and stable serverless LLM serving

USAGE: enova <COMMAND> [OPTIONS]

COMMANDS:
  serve       serve prompts on the compiled tiny LM (options: --prompts N --max-tokens N)
  serve-http  OpenAI-compatible HTTP gateway (--port 8080 --replicas 2 --engine auto|lm|sim
              --max-num-seqs N --max-tokens N --max-pending N --rate RPS --burst N
              --http-workers N --sim-delay-ms N --host ADDR --queue-budget-ms N
              --warm-pool N
              --autoscale [--min-replicas N --max-replicas N --scale-interval-ms N
              --calib-samples N --patience N --cooldown-ms N --queue-wait-budget-ms N]
              --reconfig [--reconfig-interval-ms N --reconfig-cooldown-ms N
              --reconfig-deadband F --reconfig-min-seqs N --reconfig-max-seqs N
              --reconfig-window N])
  loadgen     closed-loop load against a gateway (--addr HOST:PORT --concurrency N
              --requests N --max-tokens N [--report FILE] [--strict])
  recommend   run the service configuration module for --model <name> --gpu <name>
  detect      calibrate + run the performance detector on the trace dataset
  simulate    simulate a replica (--model --gpu --rps --seconds --max-num-seqs)
  info        print artifact manifest summary
";

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env_known(&["verbose", "autoscale", "reconfig", "strict"]);
    let cmd = args.subcommand();
    match cmd.as_str() {
        "serve" => serve(&args),
        "serve-http" => serve_http(&args),
        "loadgen" => loadgen_cmd(&args),
        "recommend" => recommend(&args),
        "detect" => detect(&args),
        "simulate" => simulate(&args),
        "info" => info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

#[cfg(feature = "xla-runtime")]
fn info() -> anyhow::Result<()> {
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    println!("artifacts: {}", m.dir.display());
    println!(
        "model: {} params, batch {}, ctx {}, vocab {} ({} / {})",
        m.model.param_count, m.model.batch, m.model.max_seq, m.model.vocab,
        m.model.decode_file, m.model.prefill_file
    );
    println!("vae: {} ({} features)", m.vae.file, m.vae.n_features);
    println!("embed: {} ({}→{})", m.embed.file, m.embed.hash_dim, m.embed.embed_dim);
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn info() -> anyhow::Result<()> {
    anyhow::bail!("`info` reads the AOT artifact manifest; rebuild with the `xla-runtime` feature")
}

#[cfg(feature = "xla-runtime")]
fn serve(args: &Args) -> anyhow::Result<()> {
    use enova::engine::{Engine, EngineConfig};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    let n = args.get_usize("prompts", 8);
    let max_tokens = args.get_usize("max-tokens", 24);
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    let rt = enova::runtime::PjRt::cpu()?;
    let lm = LmRuntime::load(rt, &m, ExecMode::Chained)?;
    let mut engine = Engine::new(
        lm,
        EngineConfig { max_num_seqs: 8, max_tokens, temperature: 0.7 },
        1,
    );
    let mut rng = enova::util::rng::Pcg64::new(2);
    for _ in 0..n {
        let fam = *rng.choice(&enova::workload::corpus::ALL_FAMILIES);
        let item = enova::workload::corpus::sample_item(fam, &mut rng);
        engine.submit(&item.text, max_tokens);
    }
    let t0 = std::time::Instant::now();
    let done = engine.run_to_completion()?;
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    println!(
        "served {} requests / {tokens} tokens in {:.2}s",
        done.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn serve(_args: &Args) -> anyhow::Result<()> {
    anyhow::bail!("`serve` drives the compiled tiny LM; rebuild with the `xla-runtime` feature")
}

/// Which engine `--engine auto` resolves to: the compiled LM when the
/// build has the runtime and the artifacts exist, the sim engine
/// otherwise.
fn auto_engine_kind() -> &'static str {
    #[cfg(feature = "xla-runtime")]
    {
        if enova::runtime::Manifest::artifacts_exist() {
            return "lm";
        }
        eprintln!("artifacts not found; serving with the deterministic sim engine");
    }
    #[cfg(not(feature = "xla-runtime"))]
    eprintln!("built without the xla-runtime feature; serving with the deterministic sim engine");
    "sim"
}

/// Reusable spawner for compiled-LM replicas (supervisor hot-add path).
#[cfg(feature = "xla-runtime")]
fn lm_spawner(
    max_num_seqs: usize,
    max_tokens: usize,
    temperature: f64,
) -> enova::gateway::EngineSpawner {
    use enova::engine::{Engine, EngineConfig, StreamEngine};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    std::sync::Arc::new(move |id| {
        let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
        let rt = enova::runtime::PjRt::cpu()?;
        let lm = LmRuntime::load(rt, &m, ExecMode::Chained)?;
        let cfg = EngineConfig {
            max_num_seqs,
            max_tokens,
            temperature,
        };
        Ok(Box::new(Engine::new(lm, cfg, 100 + id)) as Box<dyn StreamEngine>)
    })
}

/// Stub: `--engine lm` is rejected before this can ever be called.
#[cfg(not(feature = "xla-runtime"))]
fn lm_spawner(
    _max_num_seqs: usize,
    _max_tokens: usize,
    _temperature: f64,
) -> enova::gateway::EngineSpawner {
    std::sync::Arc::new(|_id| {
        Err(anyhow::anyhow!(
            "this binary was built without the xla-runtime feature"
        ))
    })
}

/// `enova serve-http`: the OpenAI-compatible serving gateway. `--engine
/// auto` (default) uses the compiled LM when artifacts exist and falls
/// back to the deterministic sim engine otherwise. With `--autoscale`,
/// the closed-loop supervisor hot-adds / retires replicas from the
/// performance detector's decisions; with `--reconfig` it also re-derives
/// `max_num_seqs`/`gpu_memory` from the live monitoring window (§IV-A)
/// and applies the verdict to running replicas. `--warm-pool N` keeps N
/// standby replicas pre-initialized so scale-ups skip engine init.
fn serve_http(args: &Args) -> anyhow::Result<()> {
    use enova::engine::sim::{SimEngine, SimEngineConfig};
    use enova::engine::StreamEngine;
    use enova::gateway::supervisor::{ReconfigPolicy, SupervisorConfig};
    use enova::gateway::{EngineSpawner, Gateway, GatewayConfig};
    use std::sync::Arc;
    use std::time::Duration;

    let replicas = args.get_usize("replicas", 2).max(1);
    let max_num_seqs = args.get_usize("max-num-seqs", 8);
    let max_tokens = args.get_usize("max-tokens", 64);
    let temperature = args.get_f64("temperature", 0.7);
    let sim_delay = Duration::from_millis(args.get_usize("sim-delay-ms", 0) as u64);

    let engine_kind = match args.get_or("engine", "auto") {
        "auto" => auto_engine_kind(),
        "lm" => "lm",
        "sim" => "sim",
        other => anyhow::bail!("--engine must be auto, lm or sim (got {other:?})"),
    };
    #[cfg(not(feature = "xla-runtime"))]
    anyhow::ensure!(
        engine_kind != "lm",
        "--engine lm needs the xla-runtime feature (rebuild with default features)"
    );

    // a reusable spawner (not one-shot factories) so the supervisor can
    // hot-add replicas beyond the initial set and pre-warm standbys
    let spawner: EngineSpawner = if engine_kind == "lm" {
        lm_spawner(max_num_seqs, max_tokens, temperature)
    } else {
        Arc::new(move |_id| {
            Ok(Box::new(SimEngine::new(SimEngineConfig {
                max_num_seqs,
                max_tokens,
                step_delay: sim_delay,
            })) as Box<dyn StreamEngine>)
        })
    };

    let autoscale = args.flag("autoscale");
    let reconfig = args.flag("reconfig");
    let reconfig_policy = reconfig.then(|| ReconfigPolicy {
        interval: Duration::from_millis(args.get_usize("reconfig-interval-ms", 10_000) as u64),
        cooldown: Duration::from_millis(args.get_usize("reconfig-cooldown-ms", 60_000) as u64),
        deadband: args.get_f64("reconfig-deadband", 0.25),
        min_max_num_seqs: args.get_usize("reconfig-min-seqs", 1).max(1),
        max_max_num_seqs: args.get_usize("reconfig-max-seqs", 256),
        window: args.get_usize("reconfig-window", 120),
        ..ReconfigPolicy::default()
    });
    let supervisor = (autoscale || reconfig).then(|| SupervisorConfig {
        sample_interval: Duration::from_millis(args.get_usize("scale-interval-ms", 1000) as u64),
        calib_samples: args.get_usize("calib-samples", 30),
        patience: args.get_usize("patience", 3),
        cooldown: Duration::from_millis(args.get_usize("cooldown-ms", 30_000) as u64),
        min_replicas: args.get_usize("min-replicas", 1).max(1),
        max_replicas: args.get_usize("max-replicas", replicas.max(4)),
        queue_wait_budget: Duration::from_millis(
            args.get_usize("queue-wait-budget-ms", 500) as u64,
        ),
        detector_scaling: autoscale,
        reconfig: reconfig_policy,
    });

    let port = args.get_usize("port", 8080);
    anyhow::ensure!(port <= u16::MAX as usize, "--port must be 0..=65535 (got {port})");
    let cfg = GatewayConfig {
        host: args.get_or("host", "127.0.0.1").to_string(),
        port: port as u16,
        max_tokens_default: max_tokens,
        max_pending: args.get_usize("max-pending", 256),
        rate_limit: args.get_f64("rate", 0.0),
        rate_burst: args.get_usize("burst", 64),
        http_workers: args.get_usize("http-workers", 64),
        queue_budget: Duration::from_millis(args.get_usize("queue-budget-ms", 0) as u64),
        warm_pool: args.get_usize("warm-pool", 0),
        ..GatewayConfig::default()
    };
    let warm_pool = cfg.warm_pool;
    let gw = Gateway::start_scalable(cfg, spawner, replicas, supervisor)?;
    println!(
        "enova gateway: {replicas}x {engine_kind} replica(s) on http://{} \
         (autoscale: {}, reconfig: {}, warm pool: {warm_pool})",
        gw.addr,
        if autoscale { "on" } else { "off" },
        if reconfig { "on" } else { "off" },
    );
    println!("  try: curl -s http://{}/healthz", gw.addr);
    gw.serve_forever();
    Ok(())
}

/// `enova loadgen`: drive a running gateway closed-loop and report. With
/// `--report FILE` the full report is written as JSON (the CI smoke job's
/// artifact); with `--strict` any transport error or non-2xx response
/// makes the command fail.
fn loadgen_cmd(args: &Args) -> anyhow::Result<()> {
    use enova::gateway::loadgen;
    let addr = args.get_or("addr", "127.0.0.1:8080").to_string();
    let cfg = loadgen::LoadgenConfig {
        concurrency: args.get_usize("concurrency", 8).max(1),
        requests_per_worker: args.get_usize("requests", 4).max(1),
        max_tokens: args.get_usize("max-tokens", 8),
        ..Default::default()
    };
    let report = loadgen::run(&addr, &cfg);
    println!("{}", report.summary());
    if let Some(path) = args.get("report") {
        std::fs::write(path, report.to_json().to_string_pretty())?;
        println!("report written to {path}");
    }
    if args.flag("strict") {
        let non_2xx: usize = report
            .status_counts
            .iter()
            .filter(|&(&code, _)| !(200..300).contains(&code))
            .map(|(_, &n)| n)
            .sum();
        anyhow::ensure!(
            report.errors == 0 && non_2xx == 0,
            "strict loadgen failed: {} transport errors, {} non-2xx responses ({:?})",
            report.errors,
            non_2xx,
            report.status_counts
        );
    }
    Ok(())
}

fn recommend(args: &Args) -> anyhow::Result<()> {
    let gpu = enova::simulator::gpu::by_name(args.get_or("gpu", "A100-80G"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let model = enova::simulator::modelcard::by_name(args.get_or("model", "L-7B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let (cfg, n_limit) = enova::bench::scenarios::enova_recommend(gpu, model, 1);
    println!("ENOVA recommendation for {} on {}:", model.name, gpu.name);
    println!("  max_num_seqs  = {}", cfg.max_num_seqs);
    println!("  max_tokens    = {}", cfg.max_tokens);
    println!("  gpu_memory    = {:.2}", cfg.gpu_memory);
    println!("  parallel_size = {}", cfg.parallel_size);
    println!("  est. n_limit  = {n_limit:.2} req/s per replica");
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn detect(_args: &Args) -> anyhow::Result<()> {
    anyhow::bail!("`detect` runs the compiled VAE; rebuild with the `xla-runtime` feature")
}

#[cfg(feature = "xla-runtime")]
fn detect(_args: &Args) -> anyhow::Result<()> {
    let m = enova::runtime::Manifest::load(&enova::runtime::Manifest::default_dir())?;
    let ds = enova::detect::dataset::DetectionDataset::load(&m.detection_dataset)?;
    let rt = enova::runtime::PjRt::cpu()?;
    let vae = enova::runtime::vae::VaeRuntime::load(rt, &m)?;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for i in (0..ds.train_rows()).step_by(4) {
        rows.extend_from_slice(ds.train_row(i));
        labels.push(ds.train_labels[i]);
    }
    let det = enova::detect::EnovaDetector::calibrate_semisupervised(vae, &rows, &labels)?;
    let scores: Vec<f64> = det.score(&ds.test)?.into_iter().map(|s| s.recon_err).collect();
    let prf = enova::detect::eval::prf_at(&ds.test_labels, &scores, det.threshold);
    println!(
        "test split: precision {:.3} recall {:.3} f1 {:.3} (threshold {:.2})",
        prf.precision, prf.recall, prf.f1, det.threshold
    );
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    use enova::simulator::replica::{Replica, ServiceConfig};
    use enova::workload::arrivals::{poisson_stream, RateProfile};
    use enova::workload::corpus::{CorpusMix, ALL_FAMILIES};
    let gpu = enova::simulator::gpu::by_name(args.get_or("gpu", "A100-80G"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu"))?;
    let model = enova::simulator::modelcard::by_name(args.get_or("model", "L-7B"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let rps = args.get_f64("rps", 5.0);
    let secs = args.get_f64("seconds", 300.0);
    let cfg = ServiceConfig {
        max_num_seqs: args.get_usize("max-num-seqs", 32),
        gpu_memory: args.get_f64("gpu-memory", 0.9),
        max_tokens: args.get_usize("max-tokens", 512),
        parallel_size: args.get_usize("parallel-size", 1),
    };
    let mut rng = enova::util::rng::Pcg64::new(3);
    let arrivals = poisson_stream(
        &RateProfile::constant(rps),
        &CorpusMix::uniform(&ALL_FAMILIES),
        secs,
        &mut rng,
    );
    let issued = arrivals.len();
    let res = Replica::new(gpu, model, cfg).simulate(arrivals, secs + 120.0);
    println!(
        "{} on {} @ {rps} rps for {secs}s: finished {}/{issued}, timed out {}, \
         {:.0} tok/gpu/s, mean norm latency {:.3}s/tok, p99 latency {:.1}s",
        model.name, gpu.name, res.finished.len(), res.timed_out,
        res.throughput_per_gpu(), res.mean_normalized_latency(), res.p99_latency()
    );
    Ok(())
}
