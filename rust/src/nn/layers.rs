//! Layers + parameter registry on top of the autograd tape.
//!
//! Parameters live outside the tape as plain matrices (`ParamSet`); each
//! training step instantiates a fresh tape, binds params as leaves, runs
//! forward/backward, and hands (param, grad) pairs to the optimizer.

use super::autograd::{Tape, Var};
use super::tensor::Matrix;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

#[derive(Clone, Default)]
pub struct ParamSet {
    pub params: BTreeMap<String, Matrix>,
}

impl ParamSet {
    pub fn new() -> ParamSet {
        ParamSet::default()
    }

    pub fn insert(&mut self, name: &str, m: Matrix) {
        self.params.insert(name.to_string(), m);
    }

    pub fn get(&self, name: &str) -> &Matrix {
        self.params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    }

    pub fn num_scalars(&self) -> usize {
        self.params.values().map(|m| m.data.len()).sum()
    }
}

/// Per-step binding of a ParamSet onto a tape.
pub struct Bound<'a> {
    pub tape: &'a Tape,
    vars: BTreeMap<String, Var>,
}

impl<'a> Bound<'a> {
    pub fn bind(tape: &'a Tape, params: &ParamSet) -> Bound<'a> {
        let vars = params
            .params
            .iter()
            .map(|(k, v)| (k.clone(), tape.leaf(v.clone())))
            .collect();
        Bound { tape, vars }
    }

    pub fn var(&self, name: &str) -> Var {
        *self
            .vars
            .get(name)
            .unwrap_or_else(|| panic!("missing bound param {name}"))
    }

    /// Linear layer `x @ W + b` using params `{prefix}.w` / `{prefix}.b`.
    pub fn linear(&self, prefix: &str, x: Var) -> Var {
        let z = self.tape.matmul(x, self.var(&format!("{prefix}.w")));
        self.tape.add_row(z, self.var(&format!("{prefix}.b")))
    }

    /// Collect gradients after backward; missing grads are zeros.
    pub fn grads(&self, params: &ParamSet) -> BTreeMap<String, Matrix> {
        self.vars
            .iter()
            .map(|(k, &v)| {
                let g = self.tape.grad(v).unwrap_or_else(|| {
                    let p = params.get(k);
                    Matrix::zeros(p.rows, p.cols)
                });
                (k.clone(), g)
            })
            .collect()
    }
}

/// Register an (in_dim → out_dim) linear layer's parameters.
pub fn init_linear(
    params: &mut ParamSet,
    prefix: &str,
    in_dim: usize,
    out_dim: usize,
    rng: &mut Pcg64,
) {
    let scale = (1.0 / in_dim as f32).sqrt();
    params.insert(&format!("{prefix}.w"), Matrix::randn(in_dim, out_dim, rng, scale));
    params.insert(&format!("{prefix}.b"), Matrix::zeros(1, out_dim));
}

/// A plain MLP: linear → tanh → ... → linear.
pub struct Mlp {
    pub prefix: String,
    pub dims: Vec<usize>,
}

impl Mlp {
    pub fn init(params: &mut ParamSet, prefix: &str, dims: &[usize], rng: &mut Pcg64) -> Mlp {
        assert!(dims.len() >= 2);
        for i in 0..dims.len() - 1 {
            init_linear(params, &format!("{prefix}.{i}"), dims[i], dims[i + 1], rng);
        }
        Mlp {
            prefix: prefix.to_string(),
            dims: dims.to_vec(),
        }
    }

    pub fn forward(&self, bound: &Bound, x: Var) -> Var {
        let mut h = x;
        let layers = self.dims.len() - 1;
        for i in 0..layers {
            h = bound.linear(&format!("{}.{i}", self.prefix), h);
            if i + 1 < layers {
                h = bound.tape.tanh(h);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_learns_xor_ish() {
        // regression: y = x0 * x1 over {-1, 1}² — nonlinear, needs hidden layer
        let mut rng = Pcg64::new(51);
        let mut params = ParamSet::new();
        let mlp = Mlp::init(&mut params, "m", &[2, 8, 1], &mut rng);
        let x = Matrix::from_rows(&[
            vec![-1.0, -1.0],
            vec![-1.0, 1.0],
            vec![1.0, -1.0],
            vec![1.0, 1.0],
        ]);
        let y = Matrix::from_vec(4, 1, vec![1.0, -1.0, -1.0, 1.0]);
        let mut opt = super::super::optim::Adam::new(0.05);
        let mut last = f32::INFINITY;
        for _ in 0..400 {
            let tape = Tape::new();
            let bound = Bound::bind(&tape, &params);
            let xin = tape.constant(x.clone());
            let target = tape.constant(y.clone());
            let pred = mlp.forward(&bound, xin);
            let loss = tape.mse(pred, target);
            tape.backward(loss);
            last = tape.value(loss).data[0];
            let grads = bound.grads(&params);
            opt.step(&mut params, &grads);
        }
        assert!(last < 0.05, "final loss {last}");
    }

    #[test]
    fn param_count() {
        let mut rng = Pcg64::new(52);
        let mut params = ParamSet::new();
        Mlp::init(&mut params, "m", &[8, 16, 4], &mut rng);
        // 8*16 + 16 + 16*4 + 4
        assert_eq!(params.num_scalars(), 8 * 16 + 16 + 16 * 4 + 4);
    }
}
