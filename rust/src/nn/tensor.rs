//! Dense row-major f32 matrix — the value type of the in-tree autograd.
//! Sized for the detection baselines (feature dims ≤ a few hundred), so
//! naive triple-loop matmul with the k-loop innermost-cache order is fine.

use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(rows * cols, data.len());
        Matrix { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Xavier/Glorot-ish init.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64, scale: f32) -> Matrix {
        let data = (0..rows * cols)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.at(i, j);
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// Add a 1×cols bias row to every row.
    pub fn add_row(&self, bias: &Matrix) -> Matrix {
        assert_eq!(bias.rows, 1);
        assert_eq!(bias.cols, self.cols);
        let mut out = self.clone();
        for r in 0..out.rows {
            for c in 0..out.cols {
                out.data[r * out.cols + c] += bias.data[c];
            }
        }
        out
    }

    /// Column-wise sum → 1×cols.
    pub fn sum_rows(&self) -> Matrix {
        let mut out = Matrix::zeros(1, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c] += self.at(r, c);
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|x| x * s)
    }

    pub fn mean_all(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::randn(4, 7, &mut rng, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn add_row_and_sum_rows_are_adjoint_shapes() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(1, 2, vec![10., 20.]);
        let c = a.add_row(&b);
        assert_eq!(c.data, vec![11., 22., 13., 24.]);
        assert_eq!(a.sum_rows().data, vec![4., 6.]);
    }

    #[test]
    fn mean_and_norm() {
        let a = Matrix::from_vec(1, 4, vec![3., 4., 0., 0.]);
        assert_eq!(a.frob_norm(), 5.0);
        assert_eq!(a.mean_all(), 1.75);
    }
}
