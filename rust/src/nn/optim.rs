//! Optimizers over [`ParamSet`]s: Adam and SGD (with optional grad clip).

use super::layers::ParamSet;
use super::tensor::Matrix;
use std::collections::BTreeMap;

pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub clip: Option<f32>,
    step: u64,
    m: BTreeMap<String, Matrix>,
    v: BTreeMap<String, Matrix>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            clip: Some(5.0),
            step: 0,
            m: BTreeMap::new(),
            v: BTreeMap::new(),
        }
    }

    pub fn step(&mut self, params: &mut ParamSet, grads: &BTreeMap<String, Matrix>) {
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        // global-norm clip
        let scale = match self.clip {
            Some(c) => {
                let norm: f32 = grads
                    .values()
                    .map(|g| g.data.iter().map(|x| x * x).sum::<f32>())
                    .sum::<f32>()
                    .sqrt();
                if norm > c {
                    c / norm
                } else {
                    1.0
                }
            }
            None => 1.0,
        };
        for (name, g) in grads {
            let p = params.params.get_mut(name).expect("param exists");
            let m = self
                .m
                .entry(name.clone())
                .or_insert_with(|| Matrix::zeros(p.rows, p.cols));
            let v = self
                .v
                .entry(name.clone())
                .or_insert_with(|| Matrix::zeros(p.rows, p.cols));
            for i in 0..p.data.len() {
                let gi = g.data[i] * scale;
                m.data[i] = self.beta1 * m.data[i] + (1.0 - self.beta1) * gi;
                v.data[i] = self.beta2 * v.data[i] + (1.0 - self.beta2) * gi * gi;
                let mh = m.data[i] / bc1;
                let vh = v.data[i] / bc2;
                p.data[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
    }
}

pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd { lr }
    }

    pub fn step(&mut self, params: &mut ParamSet, grads: &BTreeMap<String, Matrix>) {
        for (name, g) in grads {
            let p = params.params.get_mut(name).expect("param exists");
            for i in 0..p.data.len() {
                p.data[i] -= self.lr * g.data[i];
            }
        }
    }
}

/// Polyak averaging: target ← τ·source + (1−τ)·target (DDPG target nets).
pub fn soft_update(target: &mut ParamSet, source: &ParamSet, tau: f32) {
    for (name, src) in &source.params {
        let dst = target.params.get_mut(name).expect("same topology");
        for i in 0..dst.data.len() {
            dst.data[i] = tau * src.data[i] + (1.0 - tau) * dst.data[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::autograd::Tape;
    use crate::nn::layers::Bound;
    use crate::util::rng::Pcg64;

    #[test]
    fn adam_minimizes_quadratic() {
        let mut params = ParamSet::new();
        params.insert("x", Matrix::from_vec(1, 1, vec![5.0]));
        let mut opt = Adam::new(0.2);
        for _ in 0..200 {
            let tape = Tape::new();
            let bound = Bound::bind(&tape, &params);
            let x = bound.var("x");
            let loss = tape.mean_all(tape.square(x));
            tape.backward(loss);
            let grads = bound.grads(&params);
            opt.step(&mut params, &grads);
        }
        assert!(params.get("x").data[0].abs() < 1e-2);
    }

    #[test]
    fn clip_bounds_update() {
        let mut params = ParamSet::new();
        params.insert("x", Matrix::from_vec(1, 1, vec![0.0]));
        let mut opt = Adam::new(0.1);
        opt.clip = Some(1.0);
        let mut grads = BTreeMap::new();
        grads.insert("x".to_string(), Matrix::from_vec(1, 1, vec![1e6]));
        opt.step(&mut params, &grads);
        // first Adam step magnitude ≈ lr regardless, but must be finite
        assert!(params.get("x").data[0].is_finite());
    }

    #[test]
    fn soft_update_interpolates() {
        let mut rng = Pcg64::new(61);
        let mut a = ParamSet::new();
        a.insert("w", Matrix::randn(2, 2, &mut rng, 1.0));
        let mut b = ParamSet::new();
        b.insert("w", Matrix::zeros(2, 2));
        soft_update(&mut b, &a, 0.25);
        for i in 0..4 {
            assert!((b.get("w").data[i] - 0.25 * a.get("w").data[i]).abs() < 1e-7);
        }
    }
}
