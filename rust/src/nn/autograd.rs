//! Reverse-mode autodiff over [`Matrix`] — the training substrate for the
//! Table IV detection baselines (USAD, SDF-VAE-lite, Uni-AD-lite) and the
//! DDPG configuration baseline. A `Tape` records ops eagerly; `backward`
//! walks the graph in reverse, accumulating gradients.
//!
//! Gradient correctness is pinned by finite-difference property tests.

use super::tensor::Matrix;
use std::cell::RefCell;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Var(usize);

enum Op {
    Leaf,
    MatMul(Var, Var),
    Add(Var, Var),
    Sub(Var, Var),
    Mul(Var, Var),
    AddRow(Var, Var),  // broadcast bias
    Scale(Var, f32),
    Tanh(Var),
    Sigmoid(Var),
    Relu(Var),
    Exp(Var),
    Square(Var),
    MeanAll(Var),
    SumAll(Var),
    /// rows [r0, r1) of the input
    SliceRows(Var, usize, usize),
    ConcatRows(Var, Var),
    ConcatCols(Var, Var),
}

struct Node {
    op: Op,
    value: Matrix,
    grad: Option<Matrix>,
    requires_grad: bool,
}

pub struct Tape {
    nodes: RefCell<Vec<Node>>,
}

impl Default for Tape {
    fn default() -> Self {
        Self::new()
    }
}

impl Tape {
    pub fn new() -> Tape {
        Tape {
            nodes: RefCell::new(Vec::new()),
        }
    }

    fn push(&self, op: Op, value: Matrix, requires_grad: bool) -> Var {
        let mut nodes = self.nodes.borrow_mut();
        nodes.push(Node {
            op,
            value,
            grad: None,
            requires_grad,
        });
        Var(nodes.len() - 1)
    }

    pub fn leaf(&self, value: Matrix) -> Var {
        self.push(Op::Leaf, value, true)
    }

    pub fn constant(&self, value: Matrix) -> Var {
        self.push(Op::Leaf, value, false)
    }

    pub fn value(&self, v: Var) -> Matrix {
        self.nodes.borrow()[v.0].value.clone()
    }

    pub fn shape(&self, v: Var) -> (usize, usize) {
        let nodes = self.nodes.borrow();
        (nodes[v.0].value.rows, nodes[v.0].value.cols)
    }

    pub fn grad(&self, v: Var) -> Option<Matrix> {
        self.nodes.borrow()[v.0].grad.clone()
    }

    fn binary(&self, op: fn(Var, Var) -> Op, a: Var, b: Var, value: Matrix) -> Var {
        let rg = {
            let nodes = self.nodes.borrow();
            nodes[a.0].requires_grad || nodes[b.0].requires_grad
        };
        self.push(op(a, b), value, rg)
    }

    pub fn matmul(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.matmul(&nodes[b.0].value)
        };
        self.binary(Op::MatMul, a, b, value)
    }

    pub fn add(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.zip(&nodes[b.0].value, |x, y| x + y)
        };
        self.binary(Op::Add, a, b, value)
    }

    pub fn sub(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.zip(&nodes[b.0].value, |x, y| x - y)
        };
        self.binary(Op::Sub, a, b, value)
    }

    pub fn mul(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.zip(&nodes[b.0].value, |x, y| x * y)
        };
        self.binary(Op::Mul, a, b, value)
    }

    pub fn add_row(&self, a: Var, bias: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.add_row(&nodes[bias.0].value)
        };
        self.binary(Op::AddRow, a, bias, value)
    }

    pub fn scale(&self, a: Var, s: f32) -> Var {
        let value = self.nodes.borrow()[a.0].value.scale(s);
        let rg = self.nodes.borrow()[a.0].requires_grad;
        self.push(Op::Scale(a, s), value, rg)
    }

    fn unary(&self, a: Var, op: fn(Var) -> Op, f: impl Fn(f32) -> f32) -> Var {
        let value = self.nodes.borrow()[a.0].value.map(f);
        let rg = self.nodes.borrow()[a.0].requires_grad;
        self.push(op(a), value, rg)
    }

    pub fn tanh(&self, a: Var) -> Var {
        self.unary(a, Op::Tanh, |x| x.tanh())
    }

    pub fn sigmoid(&self, a: Var) -> Var {
        self.unary(a, Op::Sigmoid, |x| 1.0 / (1.0 + (-x).exp()))
    }

    pub fn relu(&self, a: Var) -> Var {
        self.unary(a, Op::Relu, |x| x.max(0.0))
    }

    pub fn exp(&self, a: Var) -> Var {
        self.unary(a, Op::Exp, |x| x.clamp(-30.0, 30.0).exp())
    }

    pub fn square(&self, a: Var) -> Var {
        self.unary(a, Op::Square, |x| x * x)
    }

    pub fn mean_all(&self, a: Var) -> Var {
        let value = Matrix::from_vec(1, 1, vec![self.nodes.borrow()[a.0].value.mean_all()]);
        let rg = self.nodes.borrow()[a.0].requires_grad;
        self.push(Op::MeanAll(a), value, rg)
    }

    pub fn sum_all(&self, a: Var) -> Var {
        let value = Matrix::from_vec(
            1,
            1,
            vec![self.nodes.borrow()[a.0].value.data.iter().sum::<f32>()],
        );
        let rg = self.nodes.borrow()[a.0].requires_grad;
        self.push(Op::SumAll(a), value, rg)
    }

    pub fn slice_rows(&self, a: Var, r0: usize, r1: usize) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            let v = &nodes[a.0].value;
            Matrix::from_vec(
                r1 - r0,
                v.cols,
                v.data[r0 * v.cols..r1 * v.cols].to_vec(),
            )
        };
        let rg = self.nodes.borrow()[a.0].requires_grad;
        self.push(Op::SliceRows(a, r0, r1), value, rg)
    }

    pub fn concat_rows(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            let (va, vb) = (&nodes[a.0].value, &nodes[b.0].value);
            assert_eq!(va.cols, vb.cols);
            let mut data = va.data.clone();
            data.extend_from_slice(&vb.data);
            Matrix::from_vec(va.rows + vb.rows, va.cols, data)
        };
        self.binary(Op::ConcatRows, a, b, value)
    }

    pub fn concat_cols(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            let (va, vb) = (&nodes[a.0].value, &nodes[b.0].value);
            assert_eq!(va.rows, vb.rows);
            let mut data = Vec::with_capacity(va.data.len() + vb.data.len());
            for r in 0..va.rows {
                data.extend_from_slice(va.row(r));
                data.extend_from_slice(vb.row(r));
            }
            Matrix::from_vec(va.rows, va.cols + vb.cols, data)
        };
        self.binary(Op::ConcatCols, a, b, value)
    }

    /// Convenience: mean squared error between `a` and `b` (scalar node).
    pub fn mse(&self, a: Var, b: Var) -> Var {
        let d = self.sub(a, b);
        let sq = self.square(d);
        self.mean_all(sq)
    }

    /// Backprop from scalar node `loss` (must be 1×1).
    pub fn backward(&self, loss: Var) {
        let n = self.nodes.borrow().len();
        {
            let mut nodes = self.nodes.borrow_mut();
            assert_eq!(
                (nodes[loss.0].value.rows, nodes[loss.0].value.cols),
                (1, 1),
                "backward() needs a scalar loss"
            );
            for node in nodes.iter_mut() {
                node.grad = None;
            }
            nodes[loss.0].grad = Some(Matrix::from_vec(1, 1, vec![1.0]));
        }

        for idx in (0..n).rev() {
            let (op_grads, targets): (Vec<Matrix>, Vec<Var>) = {
                let nodes = self.nodes.borrow();
                let node = &nodes[idx];
                let Some(g) = node.grad.as_ref() else { continue };
                if !node.requires_grad {
                    continue;
                }
                match &node.op {
                    Op::Leaf => continue,
                    Op::MatMul(a, b) => {
                        let ga = g.matmul(&nodes[b.0].value.transpose());
                        let gb = nodes[a.0].value.transpose().matmul(g);
                        (vec![ga, gb], vec![*a, *b])
                    }
                    Op::Add(a, b) => (vec![g.clone(), g.clone()], vec![*a, *b]),
                    Op::Sub(a, b) => (vec![g.clone(), g.scale(-1.0)], vec![*a, *b]),
                    Op::Mul(a, b) => {
                        let ga = g.zip(&nodes[b.0].value, |x, y| x * y);
                        let gb = g.zip(&nodes[a.0].value, |x, y| x * y);
                        (vec![ga, gb], vec![*a, *b])
                    }
                    Op::AddRow(a, bias) => {
                        (vec![g.clone(), g.sum_rows()], vec![*a, *bias])
                    }
                    Op::Scale(a, s) => (vec![g.scale(*s)], vec![*a]),
                    Op::Tanh(a) => {
                        let ga = g.zip(&node.value, |gi, y| gi * (1.0 - y * y));
                        (vec![ga], vec![*a])
                    }
                    Op::Sigmoid(a) => {
                        let ga = g.zip(&node.value, |gi, y| gi * y * (1.0 - y));
                        (vec![ga], vec![*a])
                    }
                    Op::Relu(a) => {
                        let ga = g.zip(&nodes[a.0].value, |gi, x| if x > 0.0 { gi } else { 0.0 });
                        (vec![ga], vec![*a])
                    }
                    Op::Exp(a) => {
                        let ga = g.zip(&node.value, |gi, y| gi * y);
                        (vec![ga], vec![*a])
                    }
                    Op::Square(a) => {
                        let ga = g.zip(&nodes[a.0].value, |gi, x| gi * 2.0 * x);
                        (vec![ga], vec![*a])
                    }
                    Op::MeanAll(a) => {
                        let src = &nodes[a.0].value;
                        let scale = g.data[0] / src.data.len() as f32;
                        let ga = Matrix {
                            rows: src.rows,
                            cols: src.cols,
                            data: vec![scale; src.data.len()],
                        };
                        (vec![ga], vec![*a])
                    }
                    Op::SumAll(a) => {
                        let src = &nodes[a.0].value;
                        let ga = Matrix {
                            rows: src.rows,
                            cols: src.cols,
                            data: vec![g.data[0]; src.data.len()],
                        };
                        (vec![ga], vec![*a])
                    }
                    Op::SliceRows(a, r0, _r1) => {
                        let src = &nodes[a.0].value;
                        let mut ga = Matrix::zeros(src.rows, src.cols);
                        ga.data[r0 * src.cols..r0 * src.cols + g.data.len()]
                            .copy_from_slice(&g.data);
                        (vec![ga], vec![*a])
                    }
                    Op::ConcatCols(a, b) => {
                        let (ra, ca) = {
                            let va = &nodes[a.0].value;
                            (va.rows, va.cols)
                        };
                        let cb = nodes[b.0].value.cols;
                        let mut ga = Matrix::zeros(ra, ca);
                        let mut gb = Matrix::zeros(ra, cb);
                        for r in 0..ra {
                            let row = &g.data[r * (ca + cb)..(r + 1) * (ca + cb)];
                            ga.data[r * ca..(r + 1) * ca].copy_from_slice(&row[..ca]);
                            gb.data[r * cb..(r + 1) * cb].copy_from_slice(&row[ca..]);
                        }
                        (vec![ga, gb], vec![*a, *b])
                    }
                    Op::ConcatRows(a, b) => {
                        let (ra, cols) = {
                            let va = &nodes[a.0].value;
                            (va.rows, va.cols)
                        };
                        let ga = Matrix::from_vec(ra, cols, g.data[..ra * cols].to_vec());
                        let rb = nodes[b.0].value.rows;
                        let gb =
                            Matrix::from_vec(rb, cols, g.data[ra * cols..].to_vec());
                        (vec![ga, gb], vec![*a, *b])
                    }
                }
            };
            let mut nodes = self.nodes.borrow_mut();
            for (g, t) in op_grads.into_iter().zip(targets) {
                if !nodes[t.0].requires_grad {
                    continue;
                }
                match nodes[t.0].grad.as_mut() {
                    Some(acc) => {
                        for (a, b) in acc.data.iter_mut().zip(&g.data) {
                            *a += b;
                        }
                    }
                    None => nodes[t.0].grad = Some(g),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Finite-difference check: ∂loss/∂x[i] ≈ (f(x+h) − f(x−h)) / 2h.
    fn fd_check(build: impl Fn(&Tape, Var) -> Var, x0: Matrix, tol: f32) {
        let tape = Tape::new();
        let x = tape.leaf(x0.clone());
        let loss = build(&tape, x);
        tape.backward(loss);
        let analytic = tape.grad(x).expect("grad");

        let h = 1e-3f32;
        for i in 0..x0.data.len() {
            let mut xp = x0.clone();
            xp.data[i] += h;
            let mut xm = x0.clone();
            xm.data[i] -= h;
            let tp = Tape::new();
            let fp = {
                let v = tp.leaf(xp);
                tp.value(build(&tp, v)).data[0]
            };
            let tm = Tape::new();
            let fm = {
                let v = tm.leaf(xm);
                tm.value(build(&tm, v)).data[0]
            };
            let fd = (fp - fm) / (2.0 * h);
            let a = analytic.data[i];
            assert!(
                (a - fd).abs() <= tol * (1.0 + fd.abs().max(a.abs())),
                "grad[{i}]: analytic {a} vs fd {fd}"
            );
        }
    }

    #[test]
    fn grad_mlp_chain() {
        let mut rng = Pcg64::new(41);
        let w = Matrix::randn(3, 2, &mut rng, 0.7);
        let target = Matrix::randn(4, 2, &mut rng, 1.0);
        let x0 = Matrix::randn(4, 3, &mut rng, 1.0);
        fd_check(
            move |t, x| {
                let wv = t.constant(w.clone());
                let tv = t.constant(target.clone());
                let h = t.tanh(t.matmul(x, wv));
                t.mse(h, tv)
            },
            x0,
            2e-2,
        );
    }

    #[test]
    fn grad_weight_through_bias_and_activations() {
        let mut rng = Pcg64::new(42);
        let x = Matrix::randn(5, 3, &mut rng, 1.0);
        let b0 = Matrix::randn(1, 3, &mut rng, 0.5);
        fd_check(
            move |t, bias| {
                let xv = t.constant(x.clone());
                let z = t.add_row(xv, bias);
                let s = t.sigmoid(z);
                let e = t.exp(t.scale(s, 0.3));
                t.mean_all(e)
            },
            b0,
            2e-2,
        );
    }

    #[test]
    fn grad_mul_sub_square_sum() {
        let mut rng = Pcg64::new(43);
        let y = Matrix::randn(2, 4, &mut rng, 1.0);
        let x0 = Matrix::randn(2, 4, &mut rng, 1.0);
        fd_check(
            move |t, x| {
                let yv = t.constant(y.clone());
                let p = t.mul(x, yv);
                let d = t.sub(p, x);
                let s = t.square(d);
                t.sum_all(s)
            },
            x0,
            2e-2,
        );
    }

    #[test]
    fn grad_slice_concat() {
        let mut rng = Pcg64::new(44);
        let x0 = Matrix::randn(4, 3, &mut rng, 1.0);
        fd_check(
            move |t, x| {
                let top = t.slice_rows(x, 0, 2);
                let bot = t.slice_rows(x, 2, 4);
                let swapped = t.concat_rows(bot, top);
                let s = t.square(swapped);
                t.mean_all(s)
            },
            x0,
            2e-2,
        );
    }

    #[test]
    fn grad_accumulates_on_reuse() {
        // loss = mean((x + x)²) → dloss/dx = 8x/n
        let x0 = Matrix::from_vec(1, 2, vec![1.0, -2.0]);
        let tape = Tape::new();
        let x = tape.leaf(x0);
        let s = tape.add(x, x);
        let loss = tape.mean_all(tape.square(s));
        tape.backward(loss);
        let g = tape.grad(x).unwrap();
        assert!((g.data[0] - 4.0).abs() < 1e-5, "{:?}", g.data);
        assert!((g.data[1] + 8.0).abs() < 1e-5);
    }

    #[test]
    fn relu_grad_zero_below() {
        let tape = Tape::new();
        let x = tape.leaf(Matrix::from_vec(1, 2, vec![-1.0, 2.0]));
        let loss = tape.sum_all(tape.relu(x));
        tape.backward(loss);
        assert_eq!(tape.grad(x).unwrap().data, vec![0.0, 1.0]);
    }

    #[test]
    fn constants_get_no_grad() {
        let tape = Tape::new();
        let x = tape.leaf(Matrix::from_vec(1, 1, vec![2.0]));
        let c = tape.constant(Matrix::from_vec(1, 1, vec![3.0]));
        let loss = tape.mean_all(tape.mul(x, c));
        tape.backward(loss);
        assert!(tape.grad(c).is_none());
        assert_eq!(tape.grad(x).unwrap().data, vec![3.0]);
    }
}
