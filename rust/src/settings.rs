//! Layered run configuration: `--config enova.toml`.
//!
//! The CLI grew one flag per knob; a fleet deployment wants the knobs in
//! a reviewable file instead of a 30-flag systemd unit. This module loads
//! a *subset of TOML* (hand-parsed — the offline crate set has no toml
//! crate) into an [`EnovaConfig`] and layers it **under** the parsed
//! [`Args`]: file values become defaults, explicit CLI flags always win.
//!
//! Recognized shape:
//!
//! ```toml
//! # keys before any section apply to every subcommand
//! host = "0.0.0.0"
//!
//! [gateway]        # `enova serve-http`
//! port = 8080
//! replicas = 2
//! autoscale = true # boolean true sets the --autoscale flag
//!
//! [coordinator]    # `enova serve-http --cluster`
//! port = 8080
//! forecast = true
//!
//! [node]           # `enova node`
//! coordinator = "127.0.0.1:8080"
//! gpu-memory = 24.0
//! chaos_seed = 7   # any scalar flag works, e.g. the --chaos-* /
//! chaos_error_rate = 0.2   # --breaker-* chaos-drill knobs
//!
//! [tenants.chat]   # one section per tenant -> TenantRegistry
//! tier = "latency"
//! rate_limit = 50.0
//! rate_burst = 100
//! queue_budget_ms = 250
//! api_keys = ["chat-key-1", "chat-key-2"]
//! ```
//!
//! Key names map to flag names with `_` and `-` interchangeable
//! (`queue_budget_ms` and `queue-budget-ms` are the same key). Booleans
//! map to flags: `true` sets the flag, `false` is a no-op (the CLI has no
//! negation spelling, so a file cannot un-set a flag the user passed).
//! Values are kept as their source text and parsed by the same typed
//! `Args` getters the flags use, so a file value and a flag value can
//! never disagree on parsing rules.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::gateway::admission::{SloTier, TenantSpec};
use crate::util::cli::Args;

/// One parsed scalar from the config file. Numbers keep their source
/// text so `port = 8080` reaches `Args::get_usize` as `"8080"`, not a
/// float re-rendering.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    /// numeric literal, verbatim
    Num(String),
    Bool(bool),
    /// array of strings (only used for `api_keys`)
    List(Vec<String>),
}

impl Value {
    fn as_flag_text(&self) -> Option<String> {
        match self {
            Value::Str(s) => Some(s.clone()),
            Value::Num(n) => Some(n.clone()),
            Value::Bool(_) | Value::List(_) => None,
        }
    }
}

/// The layered run configuration: top-level keys (every role), one
/// key-map per `[section]`, and the `[tenants.*]` roster.
#[derive(Debug, Default, Clone)]
pub struct EnovaConfig {
    /// keys before any `[section]` header — defaults for every subcommand
    pub global: BTreeMap<String, Value>,
    /// `[gateway]` / `[node]` / `[coordinator]` (anything else is an error)
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
    /// `[tenants.NAME]` sections, in file order
    pub tenants: Vec<TenantSpec>,
}

/// The `[section]` names a config file may declare besides `[tenants.*]`.
const ROLES: [&str; 3] = ["gateway", "node", "coordinator"];

impl EnovaConfig {
    /// Read and parse `path`; errors carry the file path and line number.
    pub fn load(path: &str) -> Result<EnovaConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --config {path}"))?;
        EnovaConfig::parse(&text).with_context(|| format!("parsing --config {path}"))
    }

    /// Parse the TOML subset described in the module docs.
    pub fn parse(text: &str) -> Result<EnovaConfig> {
        let mut cfg = EnovaConfig::default();
        // None = top-level; Some(role) = a role section; tenants are
        // accumulated into `pending` until the next header closes them
        let mut role: Option<String> = None;
        let mut tenant: Option<TenantSpec> = None;
        for (idx, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            let lineno = idx + 1;
            if line.is_empty() {
                continue;
            }
            if let Some(header) = line.strip_prefix('[') {
                let Some(name) = header.strip_suffix(']') else {
                    bail!("line {lineno}: unterminated section header {line:?}");
                };
                if let Some(t) = tenant.take() {
                    cfg.tenants.push(t);
                }
                let name = name.trim();
                if let Some(tenant_id) = name.strip_prefix("tenants.") {
                    let tenant_id = tenant_id.trim();
                    if tenant_id.is_empty() {
                        bail!("line {lineno}: [tenants.NAME] needs a tenant name");
                    }
                    tenant = Some(TenantSpec::new(tenant_id, SloTier::Standard));
                    role = None;
                } else if ROLES.contains(&name) {
                    role = Some(name.to_string());
                } else {
                    bail!(
                        "line {lineno}: unknown section [{name}]; expected [gateway], \
                         [node], [coordinator] or [tenants.NAME]"
                    );
                }
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                bail!("line {lineno}: expected `key = value`, got {line:?}");
            };
            let key = normalize_key(key.trim());
            if key.is_empty() {
                bail!("line {lineno}: empty key");
            }
            let value = parse_value(val.trim())
                .with_context(|| format!("line {lineno}: bad value for {key:?}"))?;
            if let Some(t) = tenant.as_mut() {
                apply_tenant_key(t, &key, &value)
                    .with_context(|| format!("line {lineno}: [tenants.{}]", t.id))?;
            } else if let Some(r) = &role {
                cfg.sections.entry(r.clone()).or_default().insert(key, value);
            } else {
                cfg.global.insert(key, value);
            }
        }
        if let Some(t) = tenant.take() {
            cfg.tenants.push(t);
        }
        let mut seen = std::collections::BTreeSet::new();
        for t in &cfg.tenants {
            if !seen.insert(t.id.clone()) {
                bail!("duplicate tenant section [tenants.{}]", t.id);
            }
        }
        Ok(cfg)
    }

    /// Layer this file under `args` for one role (`"gateway"`, `"node"`
    /// or `"coordinator"`): top-level keys first, then the role's
    /// section (a role key shadows a top-level key), both only where the
    /// command line did not already set the option or flag.
    pub fn apply(&self, role: &str, args: &mut Args) {
        let mut merged: BTreeMap<&String, &Value> = self.global.iter().collect();
        if let Some(section) = self.sections.get(role) {
            for (k, v) in section {
                merged.insert(k, v);
            }
        }
        for (key, value) in merged {
            let flag = key.replace('_', "-");
            match value {
                Value::Bool(true) => args.set_default_flag(&flag),
                Value::Bool(false) | Value::List(_) => {}
                other => {
                    if let Some(text) = other.as_flag_text() {
                        args.set_default(&flag, &text);
                    }
                }
            }
        }
    }
}

/// Normalize a key: `-` and `_` are interchangeable; stored with `_`.
fn normalize_key(key: &str) -> String {
    key.replace('-', "_")
}

/// Cut a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse one scalar: `"string"`, number, `true`/`false`, or a
/// `["a", "b"]` array of strings.
fn parse_value(val: &str) -> Result<Value> {
    if val.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = val.strip_prefix('"') {
        let Some(s) = inner.strip_suffix('"') else {
            bail!("unterminated string {val:?}");
        };
        if s.contains('"') {
            bail!("embedded quotes are not supported: {val:?}");
        }
        return Ok(Value::Str(s.to_string()));
    }
    if let Some(inner) = val.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            bail!("unterminated array {val:?}");
        };
        let mut items = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match parse_value(part)? {
                Value::Str(s) => items.push(s),
                other => bail!("arrays may only hold strings, got {other:?}"),
            }
        }
        return Ok(Value::List(items));
    }
    match val {
        "true" => Ok(Value::Bool(true)),
        "false" => Ok(Value::Bool(false)),
        _ if val.parse::<f64>().is_ok() => Ok(Value::Num(val.to_string())),
        _ => bail!("unrecognized value {val:?} (strings need double quotes)"),
    }
}

/// Apply one `key = value` inside a `[tenants.NAME]` section.
fn apply_tenant_key(t: &mut TenantSpec, key: &str, value: &Value) -> Result<()> {
    match (key, value) {
        ("tier", Value::Str(s)) => {
            t.tier = SloTier::parse(s)
                .with_context(|| format!("unknown tier {s:?}; expected latency, standard or batch"))?;
        }
        ("rate_limit", Value::Num(n)) => {
            t.rate_limit = n.parse().context("rate_limit must be a number")?;
        }
        ("rate_burst", Value::Num(n)) => {
            t.rate_burst = n.parse().context("rate_burst must be a non-negative integer")?;
        }
        ("queue_budget_ms", Value::Num(n)) => {
            t.queue_budget_ms = n.parse().context("queue_budget_ms must be a non-negative integer")?;
        }
        ("api_keys", Value::List(keys)) => t.api_keys = keys.clone(),
        (other, _) => bail!(
            "unknown or mistyped tenant key {other:?}; expected tier (string), rate_limit \
             (number), rate_burst (integer), queue_budget_ms (integer) or api_keys (array)"
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# fleet defaults
host = "0.0.0.0"   # applies to every role

[gateway]
port = 8080
replicas = 2
autoscale = true
forecast-headroom = 0.25

[coordinator]
port = 9090

[tenants.chat]
tier = "latency"
rate_limit = 50.0
rate_burst = 100
queue_budget_ms = 250
api_keys = ["chat-key-1", "chat-key-2"]

[tenants.codegen]
tier = "batch"
"#;

    #[test]
    fn parses_sections_tenants_and_comments() {
        let cfg = EnovaConfig::parse(SAMPLE).unwrap();
        assert_eq!(cfg.global.get("host"), Some(&Value::Str("0.0.0.0".into())));
        let gw = &cfg.sections["gateway"];
        assert_eq!(gw.get("port"), Some(&Value::Num("8080".into())));
        assert_eq!(gw.get("autoscale"), Some(&Value::Bool(true)));
        // dashes and underscores are the same key
        assert_eq!(gw.get("forecast_headroom"), Some(&Value::Num("0.25".into())));
        assert_eq!(cfg.tenants.len(), 2);
        let chat = &cfg.tenants[0];
        assert_eq!(chat.id, "chat");
        assert_eq!(chat.tier, SloTier::Latency);
        assert_eq!(chat.rate_limit, 50.0);
        assert_eq!(chat.rate_burst, 100);
        assert_eq!(chat.queue_budget_ms, 250);
        assert_eq!(chat.api_keys, vec!["chat-key-1", "chat-key-2"]);
        // unset tenant keys keep TenantSpec::new defaults
        assert_eq!(cfg.tenants[1].tier, SloTier::Batch);
        assert_eq!(cfg.tenants[1].rate_limit, 0.0);
    }

    #[test]
    fn flags_override_file_values() {
        let cfg = EnovaConfig::parse(SAMPLE).unwrap();
        let mut args = Args::parse(["--port".to_string(), "7070".to_string()]);
        cfg.apply("gateway", &mut args);
        // explicit flag wins; file fills the rest
        assert_eq!(args.get_usize("port", 0), 7070);
        assert_eq!(args.get_usize("replicas", 0), 2);
        assert_eq!(args.get_or("host", ""), "0.0.0.0");
        assert!(args.flag("autoscale"));
        assert_eq!(args.get_f64("forecast-headroom", 0.0), 0.25);
    }

    #[test]
    fn role_section_shadows_global_and_other_roles_are_ignored() {
        let cfg = EnovaConfig::parse(SAMPLE).unwrap();
        let mut args = Args::default();
        cfg.apply("coordinator", &mut args);
        assert_eq!(args.get_usize("port", 0), 9090);
        assert_eq!(args.get_or("host", ""), "0.0.0.0");
        // the gateway section's keys must not leak into the coordinator
        assert_eq!(args.get("replicas"), None);
        assert!(!args.flag("autoscale"));
    }

    #[test]
    fn chaos_and_breaker_keys_layer_like_any_flag() {
        // the layering is generic: new scalar flags (here the chaos-drill
        // and breaker knobs) work from a file with zero settings.rs code
        let cfg = EnovaConfig::parse(
            "[node]\nchaos_seed = 7\nchaos-error-rate = 0.2\n\
             [coordinator]\nbreaker_window = 40\nbreaker-cooldown-ms = 250",
        )
        .unwrap();
        let mut args = Args::default();
        cfg.apply("node", &mut args);
        assert_eq!(args.get_usize("chaos-seed", 0), 7);
        assert_eq!(args.get_f64("chaos-error-rate", 0.0), 0.2);
        let mut args = Args::parse(["--breaker-window".to_string(), "10".to_string()]);
        cfg.apply("coordinator", &mut args);
        assert_eq!(args.get_usize("breaker-window", 0), 10, "explicit flag wins");
        assert_eq!(args.get_usize("breaker-cooldown-ms", 0), 250);
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(EnovaConfig::parse("[what]").is_err());
        assert!(EnovaConfig::parse("port 8080").is_err());
        assert!(EnovaConfig::parse("port = ").is_err());
        assert!(EnovaConfig::parse("name = unquoted").is_err());
        assert!(EnovaConfig::parse("[tenants.a]\ntier = \"gold\"").is_err());
        assert!(EnovaConfig::parse("[tenants.a]\n[tenants.a]").is_err());
        assert!(EnovaConfig::parse("[tenants.]").is_err());
    }

    #[test]
    fn comment_hash_inside_string_is_kept() {
        let cfg = EnovaConfig::parse("host = \"h#1\" # real comment").unwrap();
        assert_eq!(cfg.global.get("host"), Some(&Value::Str("h#1".into())));
    }
}
