//! Synthetic request corpora standing in for gsm8k / mbpp / ARC / MC_TEST
//! (DESIGN.md §Substitutions). Each task family has prompt templates for
//! the three prompting paradigms of the paper (zero-shot, few-shot,
//! chain-of-thought), a prompt-length distribution, an output-length
//! distribution (log-normal, calibrated so the high quantiles land near
//! the paper's Table III `max_tokens` recommendations), and a base answer
//! quality used by the Fig. 5 accuracy proxy.

use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    Gsm8k,
    Mbpp,
    Arc,
    McTest,
}

pub const ALL_FAMILIES: [TaskFamily; 4] = [
    TaskFamily::Gsm8k,
    TaskFamily::Mbpp,
    TaskFamily::Arc,
    TaskFamily::McTest,
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Paradigm {
    ZeroShot,
    FewShot,
    ChainOfThought,
}

pub const ALL_PARADIGMS: [Paradigm; 3] =
    [Paradigm::ZeroShot, Paradigm::FewShot, Paradigm::ChainOfThought];

impl TaskFamily {
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::Gsm8k => "gsm8k",
            TaskFamily::Mbpp => "mbpp",
            TaskFamily::Arc => "arc",
            TaskFamily::McTest => "mc_test",
        }
    }

    /// (μ, σ) of the log-normal output-token distribution. The q99 of
    /// gsm8k ≈ 410 and mbpp ≈ 950, matching the paper's ENOVA row of
    /// Table III (max_tokens 414 / 956).
    pub fn output_lognormal(&self) -> (f64, f64) {
        match self {
            TaskFamily::Gsm8k => (5.07, 0.42),  // median ~160, q99 ~410
            TaskFamily::Mbpp => (5.80, 0.47),   // median ~330, q99 ~950
            TaskFamily::Arc => (3.40, 0.50),    // short answers, q99 ~95
            TaskFamily::McTest => (3.00, 0.45), // option picking, q99 ~55
        }
    }

    /// Mean prompt length in tokens per paradigm.
    pub fn prompt_len(&self, paradigm: Paradigm, rng: &mut Pcg64) -> usize {
        let base = match self {
            TaskFamily::Gsm8k => 110.0,
            TaskFamily::Mbpp => 160.0,
            TaskFamily::Arc => 90.0,
            TaskFamily::McTest => 260.0, // passage + question
        };
        let mult = match paradigm {
            Paradigm::ZeroShot => 1.0,
            Paradigm::FewShot => 3.2,  // k exemplars inflate the context
            Paradigm::ChainOfThought => 1.6,
        };
        (base * mult * rng.lognormal(0.0, 0.25)).round().max(8.0) as usize
    }

    pub fn sample_output_len(&self, rng: &mut Pcg64) -> usize {
        let (mu, sigma) = self.output_lognormal();
        rng.lognormal(mu, sigma).round().max(1.0) as usize
    }

    /// Base probability the model answers correctly when NOT truncated
    /// (Fig. 5 proxy; values in the ballpark of Llama-2-70B published
    /// gsm8k/mbpp scores).
    pub fn base_quality(&self) -> f64 {
        match self {
            TaskFamily::Gsm8k => 0.56,
            TaskFamily::Mbpp => 0.45,
            TaskFamily::Arc => 0.78,
            TaskFamily::McTest => 0.83,
        }
    }
}

const GSM_SUBJECTS: [&str; 6] = [
    "a farmer selling eggs at the market",
    "two trains leaving stations toward each other",
    "a class splitting pizzas for lunch",
    "a shop discounting winter jackets",
    "a cyclist riding between two towns",
    "a water tank filling from two pipes",
];

const MBPP_TASKS: [&str; 6] = [
    "find the minimum cost path in a cost matrix",
    "merge overlapping intervals in a list",
    "count distinct substrings of a string",
    "compute the nth catalan number with memoization",
    "rotate a matrix ninety degrees in place",
    "validate balanced brackets across three bracket kinds",
];

const ARC_TOPICS: [&str; 6] = [
    "why metals conduct electricity",
    "how the water cycle moves energy",
    "which organelle produces cellular energy",
    "what force keeps planets in orbit",
    "how vaccines train the immune system",
    "why the moon shows phases",
];

const MC_STORIES: [&str; 6] = [
    "a girl who lost her kite in the park",
    "a dog that learned to open doors",
    "two friends building a treehouse",
    "a boy's first day at a new school",
    "a family trip to the seaside",
    "an old clockmaker and his apprentice",
];

/// Render a realistic prompt text (used by the clusterer/embedder path).
pub fn render_prompt(family: TaskFamily, paradigm: Paradigm, rng: &mut Pcg64) -> String {
    let pick = |xs: &[&str], rng: &mut Pcg64| xs[rng.usize_in(0, xs.len())].to_string();
    let preamble = match paradigm {
        Paradigm::ZeroShot => "",
        Paradigm::FewShot => "Here are some solved examples to follow. ",
        Paradigm::ChainOfThought => "Think step by step before answering. ",
    };
    match family {
        TaskFamily::Gsm8k => format!(
            "{preamble}You are a careful math tutor. Solve this grade school \
             math word problem about {} and give the final number.",
            pick(&GSM_SUBJECTS, rng)
        ),
        TaskFamily::Mbpp => format!(
            "{preamble}You are a software development expert skilled in Python \
             programming. Write a python function to {} with concise, \
             well-documented code.",
            pick(&MBPP_TASKS, rng)
        ),
        TaskFamily::Arc => format!(
            "{preamble}Answer this science exam question: explain {} and \
             choose the correct option.",
            pick(&ARC_TOPICS, rng)
        ),
        TaskFamily::McTest => format!(
            "{preamble}Read the story about {} and answer the comprehension \
             question by picking one of four options.",
            pick(&MC_STORIES, rng)
        ),
    }
}

/// A fully materialized workload item.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub family: TaskFamily,
    pub paradigm: Paradigm,
    pub text: String,
    pub prompt_len: usize,
    pub output_len: usize,
}

pub fn sample_item(family: TaskFamily, rng: &mut Pcg64) -> WorkItem {
    let paradigm = *rng.choice(&ALL_PARADIGMS);
    WorkItem {
        family,
        paradigm,
        text: render_prompt(family, paradigm, rng),
        prompt_len: family.prompt_len(paradigm, rng),
        output_len: family.sample_output_len(rng),
    }
}

/// Mixed-corpus sampler with given family weights.
pub struct CorpusMix {
    pub families: Vec<(TaskFamily, f64)>,
}

impl CorpusMix {
    pub fn uniform(families: &[TaskFamily]) -> CorpusMix {
        CorpusMix {
            families: families.iter().map(|&f| (f, 1.0)).collect(),
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> WorkItem {
        let total: f64 = self.families.iter().map(|(_, w)| w).sum();
        let mut x = rng.f64() * total;
        for (f, w) in &self.families {
            x -= w;
            if x <= 0.0 {
                return sample_item(*f, rng);
            }
        }
        sample_item(self.families[0].0, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::descriptive::quantile;

    #[test]
    fn output_quantiles_match_table3_targets() {
        let mut rng = Pcg64::new(71);
        let q99 = |f: TaskFamily, rng: &mut Pcg64| {
            let xs: Vec<f64> = (0..20_000)
                .map(|_| f.sample_output_len(rng) as f64)
                .collect();
            quantile(&xs, 0.99)
        };
        let g = q99(TaskFamily::Gsm8k, &mut rng);
        let m = q99(TaskFamily::Mbpp, &mut rng);
        assert!((350.0..500.0).contains(&g), "gsm8k q99 {g}");
        assert!((800.0..1150.0).contains(&m), "mbpp q99 {m}");
        assert!(m > 2.0 * g); // mbpp writes much longer outputs
    }

    #[test]
    fn few_shot_prompts_are_longer() {
        let mut rng = Pcg64::new(72);
        let zs: f64 = (0..2000)
            .map(|_| TaskFamily::Gsm8k.prompt_len(Paradigm::ZeroShot, &mut rng) as f64)
            .sum::<f64>()
            / 2000.0;
        let fs: f64 = (0..2000)
            .map(|_| TaskFamily::Gsm8k.prompt_len(Paradigm::FewShot, &mut rng) as f64)
            .sum::<f64>()
            / 2000.0;
        assert!(fs > 2.0 * zs);
    }

    #[test]
    fn prompts_mention_family_vocabulary() {
        let mut rng = Pcg64::new(73);
        let g = render_prompt(TaskFamily::Gsm8k, Paradigm::ZeroShot, &mut rng);
        assert!(g.contains("math"));
        let m = render_prompt(TaskFamily::Mbpp, Paradigm::ChainOfThought, &mut rng);
        assert!(m.contains("python function"));
        assert!(m.starts_with("Think step by step"));
    }

    #[test]
    fn mix_samples_all_families() {
        let mut rng = Pcg64::new(74);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(mix.sample(&mut rng).family);
        }
        assert_eq!(seen.len(), 4);
    }
}
