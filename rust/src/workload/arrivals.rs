//! Arrival processes: Poisson (the paper's load model, after Kwon et al.),
//! load steps (Fig. 6 case study), and trace replay.

use super::corpus::CorpusMix;
use crate::simulator::replica::Request;
use crate::util::rng::Pcg64;

/// Piecewise-constant arrival intensity λ(t) in requests/second.
#[derive(Debug, Clone)]
pub struct RateProfile {
    /// (start_time, rate) segments, sorted by start time; first must be 0.
    pub segments: Vec<(f64, f64)>,
}

impl RateProfile {
    pub fn constant(rps: f64) -> RateProfile {
        RateProfile {
            segments: vec![(0.0, rps)],
        }
    }

    /// A load step: `base` rps, jumping to `peak` at `t_step`.
    pub fn step(base: f64, peak: f64, t_step: f64) -> RateProfile {
        RateProfile {
            segments: vec![(0.0, base), (t_step, peak)],
        }
    }

    pub fn rate_at(&self, t: f64) -> f64 {
        let mut rate = self.segments[0].1;
        for &(start, r) in &self.segments {
            if t >= start {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }
}

/// Generate a Poisson arrival stream over `[0, horizon)` with request
/// bodies drawn from `mix` (thinning algorithm for the non-homogeneous
/// case).
pub fn poisson_stream(
    profile: &RateProfile,
    mix: &CorpusMix,
    horizon: f64,
    rng: &mut Pcg64,
) -> Vec<Request> {
    let lambda_max = profile
        .segments
        .iter()
        .map(|&(_, r)| r)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut id = 0u64;
    loop {
        t += rng.exponential(lambda_max);
        if t >= horizon {
            break;
        }
        // thinning: accept with probability λ(t)/λ_max
        if rng.f64() <= profile.rate_at(t) / lambda_max {
            let item = mix.sample(rng);
            out.push(Request {
                id,
                arrival: t,
                prompt_len: item.prompt_len,
                gen_target: item.output_len,
                community: item.family as usize,
            });
            id += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::corpus::{TaskFamily, ALL_FAMILIES};

    #[test]
    fn constant_rate_density() {
        let mut rng = Pcg64::new(81);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let reqs = poisson_stream(&RateProfile::constant(5.0), &mix, 600.0, &mut rng);
        let rate = reqs.len() as f64 / 600.0;
        assert!((rate - 5.0).abs() < 0.35, "rate {rate}");
        // sorted arrivals, unique ids
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn step_profile_changes_density() {
        let mut rng = Pcg64::new(82);
        let mix = CorpusMix::uniform(&[TaskFamily::Gsm8k]);
        let profile = RateProfile::step(2.0, 8.0, 300.0);
        let reqs = poisson_stream(&profile, &mix, 600.0, &mut rng);
        let before = reqs.iter().filter(|r| r.arrival < 300.0).count() as f64 / 300.0;
        let after = reqs.iter().filter(|r| r.arrival >= 300.0).count() as f64 / 300.0;
        assert!((before - 2.0).abs() < 0.5, "before {before}");
        assert!((after - 8.0).abs() < 1.0, "after {after}");
    }

    #[test]
    fn rate_at_boundaries() {
        let p = RateProfile::step(1.0, 4.0, 10.0);
        assert_eq!(p.rate_at(0.0), 1.0);
        assert_eq!(p.rate_at(9.999), 1.0);
        assert_eq!(p.rate_at(10.0), 4.0);
    }
}
