//! DDPG (Lillicrap et al.) configuration baseline: deterministic actor +
//! Q critic on the in-tree autograd, exploring the config cube with
//! Gaussian action noise and replay. The config-search task is a
//! contextual bandit (one-step episodes: state = workload profile stats,
//! action = config point, reward = throughput), which is how the paper's
//! baseline uses it.

use super::{ConfigSpace, ThroughputEnv};
use crate::nn::autograd::Tape;
use crate::nn::layers::{Bound, Mlp, ParamSet};
use crate::nn::optim::Adam;
use crate::nn::tensor::Matrix;
use crate::simulator::replica::ServiceConfig;
use crate::util::rng::Pcg64;

pub struct DdpgOpts {
    pub episodes: usize,
    pub batch: usize,
    pub actor_lr: f32,
    pub critic_lr: f32,
    pub noise: f64,
    pub noise_decay: f64,
    pub seed: u64,
}

impl Default for DdpgOpts {
    fn default() -> Self {
        DdpgOpts {
            episodes: 24,
            batch: 16,
            actor_lr: 2e-3,
            critic_lr: 4e-3,
            noise: 0.35,
            noise_decay: 0.92,
            seed: 44,
        }
    }
}

#[derive(Debug, Clone)]
pub struct DdpgResult {
    pub config: ServiceConfig,
    pub best_throughput: f64,
    pub evaluations: usize,
    pub history: Vec<(ServiceConfig, f64)>,
}

const STATE_DIM: usize = 4;
const ACTION_DIM: usize = 3;

fn actor_forward(bound: &Bound, actor: &Mlp, state: crate::nn::autograd::Var) -> crate::nn::autograd::Var {
    // sigmoid squashes into the unit cube
    bound.tape.sigmoid(actor.forward(bound, state))
}

/// Run DDPG against the throughput environment.
pub fn optimize(env: &ThroughputEnv, space: &ConfigSpace, opts: &DdpgOpts) -> DdpgResult {
    let mut rng = Pcg64::new(opts.seed);
    let mut params = ParamSet::new();
    let actor = Mlp::init(&mut params, "actor", &[STATE_DIM, 16, ACTION_DIM], &mut rng);
    let critic = Mlp::init(
        &mut params,
        "critic",
        &[STATE_DIM + ACTION_DIM, 24, 1],
        &mut rng,
    );
    let mut actor_opt = Adam::new(opts.actor_lr);
    let mut critic_opt = Adam::new(opts.critic_lr);

    // fixed workload context (rate, mean prompt, mean output, horizon)
    let n = env.arrivals.len() as f64;
    let state_vec = vec![
        (n / env.horizon.max(1.0) / 20.0) as f32,
        (env.arrivals.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / n / 1000.0) as f32,
        (env.arrivals.iter().map(|r| r.gen_target).sum::<usize>() as f64 / n / 1000.0) as f32,
        (env.horizon / 1000.0) as f32,
    ];
    let state_row = Matrix::from_vec(1, STATE_DIM, state_vec.clone());

    let mut replay: Vec<([f64; 3], f64)> = Vec::new();
    let mut history = Vec::new();
    let mut noise = opts.noise;
    let mut reward_scale = 1.0f64;

    for _ in 0..opts.episodes {
        // act: μ(s) + N
        let tape = Tape::new();
        let bound = Bound::bind(&tape, &params);
        let s = tape.constant(state_row.clone());
        let a = tape.value(actor_forward(&bound, &actor, s));
        let mut action = [0.0f64; 3];
        for (i, item) in action.iter_mut().enumerate() {
            *item = (a.data[i] as f64 + rng.normal() * noise).clamp(0.0, 1.0);
        }
        noise *= opts.noise_decay;

        let cfg = space.decode(&action);
        let reward = env.evaluate(cfg);
        history.push((cfg, reward));
        reward_scale = reward_scale.max(reward);
        replay.push((action, reward));

        // critic update on replayed minibatch (terminal episodes: target=r)
        let k = replay.len().min(opts.batch);
        let mut rows = Vec::with_capacity(k);
        let mut targets = Vec::with_capacity(k);
        for _ in 0..k {
            let (act, rew) = replay[rng.usize_in(0, replay.len())];
            let mut row = state_vec.clone();
            row.extend(act.iter().map(|&x| x as f32));
            rows.push(row);
            targets.push((rew / reward_scale) as f32);
        }
        {
            let tape = Tape::new();
            let bound = Bound::bind(&tape, &params);
            let sa = tape.constant(Matrix::from_rows(&rows));
            let q = critic.forward(&bound, sa);
            let t = tape.constant(Matrix::from_vec(k, 1, targets));
            let loss = tape.mse(q, t);
            tape.backward(loss);
            let grads: std::collections::BTreeMap<String, Matrix> = bound
                .grads(&params)
                .into_iter()
                .filter(|(k, _)| k.starts_with("critic"))
                .collect();
            critic_opt.step(&mut params, &grads);
        }

        // actor update: ascend Q(s, μ(s)) — gradient flows through the
        // critic into the actor's parameters (critic params filtered out)
        {
            let tape = Tape::new();
            let bound = Bound::bind(&tape, &params);
            let s = tape.constant(state_row.clone());
            let a = actor_forward(&bound, &actor, s);
            let sa = tape.concat_cols(tape.constant(state_row.clone()), a);
            let q = critic.forward(&bound, sa);
            let loss = tape.mean_all(tape.scale(q, -1.0));
            tape.backward(loss);
            let grads: std::collections::BTreeMap<String, Matrix> = bound
                .grads(&params)
                .into_iter()
                .filter(|(k, _)| k.starts_with("actor"))
                .collect();
            actor_opt.step(&mut params, &grads);
        }
    }

    let (bi, _) = history
        .iter()
        .enumerate()
        .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
        .unwrap();
    DdpgResult {
        config: history[bi].0,
        best_throughput: history[bi].1,
        evaluations: history.len(),
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_cols_grad_flows_to_action_only() {
        // mirrors the actor update: constant state ‖ variable action
        let tape = Tape::new();
        let s = tape.constant(Matrix::from_vec(1, 2, vec![5.0, 6.0]));
        let a = tape.leaf(Matrix::from_vec(1, 2, vec![1.0, 2.0]));
        let sa = tape.concat_cols(s, a);
        assert_eq!(tape.value(sa).data, vec![5.0, 6.0, 1.0, 2.0]);
        let loss = tape.mean_all(tape.square(sa));
        tape.backward(loss);
        let g = tape.grad(a).unwrap();
        // d mean(x²)/da_i = 2 a_i / 4
        assert!((g.data[0] - 0.5).abs() < 1e-6);
        assert!((g.data[1] - 1.0).abs() < 1e-6);
        assert!(tape.grad(s).is_none());
    }

    #[test]
    fn ddpg_learns_on_synthetic_bandit() {
        // reward peaked at action (0.8, 0.2, 0.5): the actor should drift
        // toward it (we check the best-found reward, as the paper's use is
        // best-config extraction, not policy convergence)
        use crate::simulator::gpu::A100_80G;
        use crate::simulator::modelcard::LLAMA2_7B;
        use crate::workload::arrivals::{poisson_stream, RateProfile};
        use crate::workload::corpus::{CorpusMix, ALL_FAMILIES};
        let mut rng = Pcg64::new(9);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(12.0), &mix, 60.0, &mut rng);
        let env = ThroughputEnv {
            gpu: &A100_80G,
            model: &LLAMA2_7B,
            arrivals,
            horizon: 120.0,
        };
        let space = ConfigSpace::for_model(&A100_80G, &LLAMA2_7B);
        let opts = DdpgOpts {
            episodes: 10,
            ..Default::default()
        };
        let res = optimize(&env, &space, &opts);
        assert_eq!(res.evaluations, 10);
        assert!(res.best_throughput > 0.0);
        assert!(res.config.max_num_seqs >= 4);
    }
}
