//! Configuration-search baselines of §VI-A: **Default**, **COSE** (GP
//! Bayesian optimization, Akhtar et al.) and **DDPG** (Lillicrap et al.),
//! all maximizing LLM-service *throughput* on the simulator environment —
//! which is exactly why they over-provision `max_num_seqs`/`max_tokens`
//! relative to ENOVA (the paper's Table III observation).

pub mod cose;
pub mod ddpg;

use crate::simulator::gpu::GpuSpec;
use crate::simulator::modelcard::ModelCard;
use crate::simulator::replica::{Replica, Request, ServiceConfig};

/// Continuous search space (unit cube) ↔ ServiceConfig mapping shared by
/// COSE and DDPG.
#[derive(Debug, Clone, Copy)]
pub struct ConfigSpace {
    pub seqs_range: (f64, f64),   // log2 space
    pub tokens_range: (f64, f64), // log2 space
    pub mem_range: (f64, f64),
    pub parallel_size: usize,
}

impl ConfigSpace {
    pub fn for_model(gpu: &'static GpuSpec, model: &'static ModelCard) -> ConfigSpace {
        // smallest TP group that fits the weights
        let mut p = 1;
        while p < 64 {
            let pooled = gpu.mem_bytes * p as f64 * 0.95;
            if pooled > model.weight_bytes() * 1.1 {
                break;
            }
            p *= 2;
        }
        ConfigSpace {
            seqs_range: (2.0, 9.0),    // 4..512
            tokens_range: (6.0, 12.0), // 64..4096
            mem_range: (0.5, 0.95),
            parallel_size: p,
        }
    }

    /// Map a point in [0,1]³ to a concrete config.
    pub fn decode(&self, x: &[f64; 3]) -> ServiceConfig {
        let lerp = |r: (f64, f64), t: f64| r.0 + (r.1 - r.0) * t.clamp(0.0, 1.0);
        ServiceConfig {
            max_num_seqs: 2f64.powf(lerp(self.seqs_range, x[0])).round() as usize,
            max_tokens: 2f64.powf(lerp(self.tokens_range, x[1])).round() as usize,
            gpu_memory: lerp(self.mem_range, x[2]),
            parallel_size: self.parallel_size,
        }
    }
}

/// The shared objective: throughput (tokens/GPU/s) of a short overload
/// simulation — the baselines' stated optimization target.
pub struct ThroughputEnv {
    pub gpu: &'static GpuSpec,
    pub model: &'static ModelCard,
    pub arrivals: Vec<Request>,
    pub horizon: f64,
}

impl ThroughputEnv {
    pub fn evaluate(&self, cfg: ServiceConfig) -> f64 {
        let rep = Replica::new(self.gpu, self.model, cfg);
        if !rep.fits() {
            return 0.0;
        }
        rep.simulate(self.arrivals.clone(), self.horizon)
            .throughput_per_gpu()
    }
}

/// The "Default" baseline: vLLM-ish defaults, no tuning (Table III row 1).
pub fn default_config(space: &ConfigSpace) -> ServiceConfig {
    ServiceConfig {
        max_num_seqs: 8,
        max_tokens: 256,
        gpu_memory: 0.9,
        parallel_size: space.parallel_size,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gpu::A100_80G;
    use crate::simulator::modelcard::{LLAMA2_70B, LLAMA2_7B};

    #[test]
    fn space_decodes_bounds() {
        let s = ConfigSpace::for_model(&A100_80G, &LLAMA2_7B);
        let lo = s.decode(&[0.0, 0.0, 0.0]);
        let hi = s.decode(&[1.0, 1.0, 1.0]);
        assert_eq!(lo.max_num_seqs, 4);
        assert_eq!(hi.max_num_seqs, 512);
        assert_eq!(lo.max_tokens, 64);
        assert_eq!(hi.max_tokens, 4096);
        assert!((lo.gpu_memory - 0.5).abs() < 1e-9);
        assert_eq!(lo.parallel_size, 1);
    }

    #[test]
    fn seventy_b_space_uses_tp() {
        let s = ConfigSpace::for_model(&A100_80G, &LLAMA2_70B);
        assert!(s.parallel_size >= 2);
    }
}
