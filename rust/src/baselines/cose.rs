//! COSE (Akhtar et al., INFOCOM'20): configuration search with Gaussian
//! Process Bayesian Optimization — RBF kernel, expected-improvement
//! acquisition, random candidate sampling.

use super::{ConfigSpace, ThroughputEnv};
use crate::simulator::replica::ServiceConfig;
use crate::stats::tdist::norm_cdf;
use crate::util::rng::Pcg64;

pub struct CoseOpts {
    pub init_points: usize,
    pub iterations: usize,
    pub candidates: usize,
    pub length_scale: f64,
    pub noise: f64,
    pub seed: u64,
}

impl Default for CoseOpts {
    fn default() -> Self {
        CoseOpts {
            init_points: 6,
            iterations: 18,
            candidates: 256,
            length_scale: 0.3,
            noise: 1e-3,
            seed: 33,
        }
    }
}

fn rbf(a: &[f64; 3], b: &[f64; 3], ls: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (-0.5 * d2 / (ls * ls)).exp()
}

/// Cholesky factorization of a symmetric PD matrix (in place, lower).
fn cholesky(a: &mut Vec<Vec<f64>>) -> bool {
    let n = a.len();
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i][j];
            for k in 0..j {
                s -= a[i][k] * a[j][k];
            }
            if i == j {
                if s <= 0.0 {
                    return false;
                }
                a[i][j] = s.sqrt();
            } else {
                a[i][j] = s / a[j][j];
            }
        }
        for j in i + 1..n {
            a[i][j] = 0.0;
        }
    }
    true
}

fn chol_solve(l: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = l.len();
    // forward
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    // backward (Lᵀ x = y)
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    x
}

struct Gp {
    xs: Vec<[f64; 3]>,
    l: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    ls: f64,
    y_mean: f64,
    y_std: f64,
}

impl Gp {
    fn fit(xs: &[[f64; 3]], ys: &[f64], ls: f64, noise: f64) -> Option<Gp> {
        let n = xs.len();
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let y_std = (ys.iter().map(|y| (y - y_mean).powi(2)).sum::<f64>() / n as f64)
            .sqrt()
            .max(1e-9);
        let yn: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let mut k = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                k[i][j] = rbf(&xs[i], &xs[j], ls);
            }
            k[i][i] += noise;
        }
        if !cholesky(&mut k) {
            return None;
        }
        let alpha = chol_solve(&k, &yn);
        Some(Gp {
            xs: xs.to_vec(),
            l: k,
            alpha,
            ls,
            y_mean,
            y_std,
        })
    }

    /// Posterior mean + std at x (normalized space).
    fn predict(&self, x: &[f64; 3]) -> (f64, f64) {
        let kstar: Vec<f64> = self.xs.iter().map(|xi| rbf(xi, x, self.ls)).collect();
        let mean_n: f64 = kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        // var = k(x,x) − vᵀv with L v = k*
        let n = self.xs.len();
        let mut v = vec![0.0; n];
        for i in 0..n {
            let mut s = kstar[i];
            for k in 0..i {
                s -= self.l[i][k] * v[k];
            }
            v[i] = s / self.l[i][i];
        }
        let var = (1.0 - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (
            mean_n * self.y_std + self.y_mean,
            var.sqrt() * self.y_std,
        )
    }
}

fn expected_improvement(mean: f64, std: f64, best: f64) -> f64 {
    if std <= 1e-12 {
        return 0.0;
    }
    let z = (mean - best) / std;
    let pdf = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
    (mean - best) * norm_cdf(z) + std * pdf
}

#[derive(Debug, Clone)]
pub struct CoseResult {
    pub config: ServiceConfig,
    pub best_throughput: f64,
    pub evaluations: usize,
    pub history: Vec<(ServiceConfig, f64)>,
}

/// Run COSE against the throughput environment.
pub fn optimize(env: &ThroughputEnv, space: &ConfigSpace, opts: &CoseOpts) -> CoseResult {
    let mut rng = Pcg64::new(opts.seed);
    let mut xs: Vec<[f64; 3]> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut history = Vec::new();
    let mut sample = |rng: &mut Pcg64| [rng.f64(), rng.f64(), rng.f64()];
    for _ in 0..opts.init_points {
        let x = sample(&mut rng);
        let cfg = space.decode(&x);
        let y = env.evaluate(cfg);
        history.push((cfg, y));
        xs.push(x);
        ys.push(y);
    }
    for _ in 0..opts.iterations {
        let best = ys.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let next = match Gp::fit(&xs, &ys, opts.length_scale, opts.noise) {
            Some(gp) => {
                let mut cand_best = (sample(&mut rng), f64::NEG_INFINITY);
                for _ in 0..opts.candidates {
                    let x = sample(&mut rng);
                    let (m, s) = gp.predict(&x);
                    let ei = expected_improvement(m, s, best);
                    if ei > cand_best.1 {
                        cand_best = (x, ei);
                    }
                }
                cand_best.0
            }
            None => sample(&mut rng),
        };
        let cfg = space.decode(&next);
        let y = env.evaluate(cfg);
        history.push((cfg, y));
        xs.push(next);
        ys.push(y);
    }
    let (bi, _) = ys
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap();
    CoseResult {
        config: space.decode(&xs[bi]),
        best_throughput: ys[bi],
        evaluations: ys.len(),
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gp_interpolates_training_points() {
        let xs = vec![[0.1, 0.1, 0.1], [0.9, 0.9, 0.9], [0.5, 0.2, 0.8]];
        let ys = vec![1.0, 3.0, 2.0];
        let gp = Gp::fit(&xs, &ys, 0.3, 1e-6).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let (m, s) = gp.predict(x);
            assert!((m - y).abs() < 0.05, "mean {m} vs {y}");
            assert!(s < 0.1, "train-point std {s}");
        }
        // far from data: high uncertainty
        let (_, s) = gp.predict(&[0.0, 1.0, 0.0]);
        assert!(s > 0.3);
    }

    #[test]
    fn ei_prefers_uncertain_or_better() {
        let a = expected_improvement(1.0, 0.1, 0.5); // clearly better
        let b = expected_improvement(0.4, 0.1, 0.5); // clearly worse
        let c = expected_improvement(0.4, 1.0, 0.5); // worse mean, uncertain
        assert!(a > c && c > b);
    }

    #[test]
    fn bo_finds_peak_of_synthetic_objective() {
        // objective peaked at x = (0.7, 0.3, 0.5) — no simulator needed
        struct Fake;
        impl Fake {
            fn eval(&self, x: &[f64; 3]) -> f64 {
                let d2 = (x[0] - 0.7).powi(2) + (x[1] - 0.3).powi(2) + (x[2] - 0.5).powi(2);
                (-4.0 * d2).exp()
            }
        }
        let f = Fake;
        let mut rng = Pcg64::new(1);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..6 {
            let x = [rng.f64(), rng.f64(), rng.f64()];
            ys.push(f.eval(&x));
            xs.push(x);
        }
        for _ in 0..25 {
            let best = ys.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let gp = Gp::fit(&xs, &ys, 0.3, 1e-4).unwrap();
            let mut cand = ([0.0; 3], f64::NEG_INFINITY);
            for _ in 0..256 {
                let x = [rng.f64(), rng.f64(), rng.f64()];
                let (m, s) = gp.predict(&x);
                let ei = expected_improvement(m, s, best);
                if ei > cand.1 {
                    cand = (x, ei);
                }
            }
            ys.push(f.eval(&cand.0));
            xs.push(cand.0);
        }
        let best = ys.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        assert!(best > 0.95, "BO best {best}");
    }
}
