//! The autoscaling control loop (§IV-B + §V): monitor → detect → localize
//! (MD up/down) → re-run the configuration module → redeploy.
//!
//! Runs against the discrete-event simulator in windowed segments (each
//! reconfiguration relaunches the service, exactly like the Fig. 6 case
//! study where Mistral-7B's gpu_memory is bumped 90%→95% and the replica
//! restarts ~7 simulated minutes after detection).
//!
//! The *live* counterpart — the same detect → act loop executed against
//! real engine workers inside the serving process, with replica
//! hot-add/retire instead of simulated relaunches — is
//! [`crate::gateway::supervisor`]; it shares this module's [`Action`]
//! vocabulary.

use crate::detect::{ScaleDirection, ZscoreDetector};
use crate::metrics::Frame;
use crate::simulator::gpu::GpuSpec;
use crate::simulator::modelcard::ModelCard;
use crate::simulator::replica::{Replica, Request, ServiceConfig, SimResult};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// raise gpu_memory (KV starvation at unchanged demand)
    RaiseGpuMemory { from: f64, to: f64 },
    /// add a replica (sustained overload) — not used in the single-replica
    /// case study but exercised by the cluster example
    AddReplica,
    /// lower gpu_memory / remove replica on sustained underload
    ScaleDown,
    /// re-derive the Table I knobs from the live monitoring window
    /// (§IV-A on the serving path) and apply them to running replicas
    /// without a relaunch — the gateway supervisor's reconfiguration loop
    Reconfigure { max_num_seqs: usize, gpu_memory: f64 },
}

#[derive(Debug, Clone)]
pub struct ScalingEvent {
    pub t: f64,
    pub detected_kl: f64,
    pub direction: ScaleDirection,
    pub action: Action,
    /// when the relaunched service is back up
    pub effective_at: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct AutoscalerOpts {
    /// detection window (seconds of frames fed to the detector)
    pub window: usize,
    /// consecutive anomalous windows required to act
    pub patience: usize,
    /// service relaunch time after a reconfiguration (s) — the Fig. 6 case
    /// shows ~7 min from detection to relaunch
    pub relaunch_delay: f64,
    /// cooldown after an action (s)
    pub cooldown: f64,
    pub gpu_memory_step: f64,
    pub gpu_memory_max: f64,
}

impl Default for AutoscalerOpts {
    fn default() -> Self {
        AutoscalerOpts {
            window: 30,
            patience: 3,
            relaunch_delay: 420.0,
            cooldown: 600.0,
            gpu_memory_step: 0.05,
            gpu_memory_max: 0.95,
        }
    }
}

#[derive(Debug, Clone)]
pub struct AutoscaleRun {
    pub events: Vec<ScalingEvent>,
    pub frames: Vec<(f64, Frame)>,
    pub finished: usize,
    pub timed_out: usize,
    pub final_config: ServiceConfig,
    /// finished-requests/s over the segment before the first action and
    /// after the last action became effective (the Fig. 6 "1.6×" number)
    pub rps_before: f64,
    pub rps_after: f64,
}

/// Run one replica with the autoscaling loop closed over it.
///
/// The detector is calibrated on the first `calib` seconds (assumed
/// healthy), then each subsequent window is scored; `patience` anomalous
/// windows with MD>0 trigger the configuration module's remedial action.
pub fn run_with_autoscaling(
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
    initial: ServiceConfig,
    arrivals: Vec<Request>,
    horizon: f64,
    calib: f64,
    opts: &AutoscalerOpts,
) -> AutoscaleRun {
    let mut cfg = initial;
    let mut events: Vec<ScalingEvent> = Vec::new();
    let mut all_frames: Vec<(f64, Frame)> = Vec::new();
    let mut finished = 0usize;
    let mut timed_out = 0usize;

    // ---- segment 1: run until first detection (or horizon) ------------
    let rep = Replica::new(gpu, model, cfg);
    let res = rep.simulate(arrivals.clone(), horizon);

    // The monitoring system samples at 1 Hz but the detector consumes
    // window-averaged frames (the paper monitors at 1-minute cadence) —
    // transient second-scale bursts are not anomalies.
    let win = opts.window.max(1);
    let averaged: Vec<(f64, [f64; 8])> = res
        .frames
        .chunks(win)
        .filter(|c| !c.is_empty())
        .map(|chunk| {
            let mut acc = [0.0; 8];
            for (_, f) in chunk {
                for (a, v) in acc.iter_mut().zip(f.to_array()) {
                    *a += v;
                }
            }
            for a in acc.iter_mut() {
                *a /= chunk.len() as f64;
            }
            (chunk[chunk.len() - 1].0, acc)
        })
        .collect();
    let calib_windows = (calib as usize / win).max(1);
    let calib_rows: Vec<f64> = averaged
        .iter()
        .take(calib_windows)
        .flat_map(|(_, a)| a.iter().copied())
        .collect();
    let detector = ZscoreDetector::calibrate(&calib_rows, 8);

    let mut detect_t: Option<(f64, f64, ScaleDirection)> = None;
    if let Some(det) = &detector {
        let mut streak = 0usize;
        for (t, row) in averaged.iter().skip(calib_windows) {
            let d = det.detect_row(row);
            if d.is_anomaly {
                streak += 1;
                if streak >= opts.patience {
                    detect_t = Some((*t, d.kl, d.direction));
                    break;
                }
            } else {
                streak = 0;
            }
        }
    }

    let Some((t_detect, kl, direction)) = detect_t else {
        // no anomaly for the whole run
        let rps = res.finished_rps();
        return AutoscaleRun {
            events,
            frames: res.frames.clone(),
            finished: res.finished.len(),
            timed_out: res.timed_out,
            final_config: cfg,
            rps_before: rps,
            rps_after: rps,
        };
    };

    // truncate segment 1 at the moment the relaunch happens
    let t_effective = t_detect + opts.relaunch_delay;
    let seg1 = rep.simulate(
        arrivals
            .iter()
            .copied()
            .filter(|r| r.arrival < t_effective)
            .collect(),
        t_effective,
    );
    finished += seg1.finished.len();
    timed_out += seg1.timed_out;
    all_frames.extend(seg1.frames.iter().cloned());
    let window_before = 120.0f64.min(t_detect);
    let rps_before = seg1
        .finished
        .iter()
        .filter(|f| f.finish >= t_detect - window_before && f.finish < t_detect)
        .count() as f64
        / window_before.max(1.0);

    // ---- act: configuration module picks the remedial change ----------
    let action = match direction {
        ScaleDirection::Up => {
            if cfg.gpu_memory < opts.gpu_memory_max - 1e-9 {
                let from = cfg.gpu_memory;
                cfg.gpu_memory = (cfg.gpu_memory + opts.gpu_memory_step).min(opts.gpu_memory_max);
                Action::RaiseGpuMemory {
                    from,
                    to: cfg.gpu_memory,
                }
            } else {
                Action::AddReplica
            }
        }
        ScaleDirection::Down => Action::ScaleDown,
    };
    events.push(ScalingEvent {
        t: t_detect,
        detected_kl: kl,
        direction,
        action,
        effective_at: t_effective,
    });

    // ---- segment 2: relaunched service absorbs leftover + future ------
    let mut seg2_arrivals = seg1.leftover.clone();
    seg2_arrivals.extend(
        arrivals
            .iter()
            .copied()
            .filter(|r| r.arrival >= t_effective),
    );
    // shift timeline so segment 2 starts at 0 internally
    for r in seg2_arrivals.iter_mut() {
        r.arrival = (r.arrival - t_effective).max(0.0);
    }
    let rep2 = Replica::new(gpu, model, cfg);
    let seg2 = rep2.simulate(seg2_arrivals, horizon - t_effective);
    finished += seg2.finished.len();
    timed_out += seg2.timed_out;
    for (t, f) in &seg2.frames {
        all_frames.push((t + t_effective, *f));
    }
    let rps_after = steady_rps(&seg2, 120.0);

    AutoscaleRun {
        events,
        frames: all_frames,
        finished,
        timed_out,
        final_config: cfg,
        rps_before,
        rps_after,
    }
}

fn steady_rps(res: &SimResult, tail_window: f64) -> f64 {
    let t1 = res.horizon;
    let t0 = (t1 - tail_window).max(0.0);
    res.finished
        .iter()
        .filter(|f| f.finish >= t0 && f.finish < t1)
        .count() as f64
        / (t1 - t0).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gpu::RTX4090_24G;
    use crate::simulator::modelcard::MISTRAL_7B;
    use crate::util::rng::Pcg64;
    use crate::workload::arrivals::{poisson_stream, RateProfile};
    use crate::workload::corpus::{CorpusMix, TaskFamily};

    /// The Fig. 6 scenario: Mistral-7B on one RTX4090 at gpu_memory 0.90,
    /// load steps up → KV saturation → detector fires → gpu_memory 0.95 →
    /// relaunch sustains more requests.
    fn fig6_setup(seed: u64) -> (ServiceConfig, Vec<Request>) {
        let cfg = ServiceConfig {
            max_num_seqs: 48,
            gpu_memory: 0.90,
            max_tokens: 512,
            parallel_size: 1,
        };
        let mix = CorpusMix::uniform(&[TaskFamily::Gsm8k, TaskFamily::Mbpp]);
        let mut rng = Pcg64::new(seed);
        // base load within capacity, stepping past it at t=1200
        let profile = RateProfile::step(2.0, 6.5, 1200.0);
        let arrivals = poisson_stream(&profile, &mix, 3600.0, &mut rng);
        (cfg, arrivals)
    }

    #[test]
    fn case_study_detects_and_scales_up() {
        let (cfg, arrivals) = fig6_setup(42);
        let run = run_with_autoscaling(
            &RTX4090_24G,
            &MISTRAL_7B,
            cfg,
            arrivals,
            3600.0,
            600.0,
            &AutoscalerOpts::default(),
        );
        assert_eq!(run.events.len(), 1, "expected one scaling event: {run:?}");
        let ev = &run.events[0];
        assert!(ev.t >= 1200.0, "detected before the load step: {}", ev.t);
        assert!(ev.t < 2000.0, "detection too slow: {}", ev.t);
        assert!(matches!(ev.action, Action::RaiseGpuMemory { .. }));
        assert!(run.final_config.gpu_memory > 0.94);
        // the relaunched service sustains more than the saturated one
        assert!(
            run.rps_after > run.rps_before,
            "after {} !> before {}",
            run.rps_after,
            run.rps_before
        );
    }

    #[test]
    fn healthy_service_never_scales() {
        let cfg = ServiceConfig {
            max_num_seqs: 48,
            gpu_memory: 0.9,
            max_tokens: 512,
            parallel_size: 1,
        };
        let mix = CorpusMix::uniform(&[TaskFamily::Gsm8k]);
        let mut rng = Pcg64::new(7);
        let arrivals = poisson_stream(&RateProfile::constant(1.5), &mix, 1800.0, &mut rng);
        let run = run_with_autoscaling(
            &RTX4090_24G,
            &MISTRAL_7B,
            cfg,
            arrivals,
            1800.0,
            600.0,
            &AutoscalerOpts::default(),
        );
        assert!(run.events.is_empty(), "spurious events: {:?}", run.events);
    }
}
