//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) onto a CPU
//! PJRT client and expose typed wrappers over them. This is the only
//! module that touches the `xla` crate; nothing in it calls Python.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! Hot-path design (EXPERIMENTS.md §Perf): every lowered program has a
//! single array root, so its output `PjRtBuffer` feeds the next call via
//! `execute_b` — the LM's KV cache stays device-resident across the whole
//! generation, and only the `B×V` logits tail is copied to the host per
//! step (`copy_raw_to_host_sync` with offset).

pub mod embedder;
pub mod lm;
pub mod vae;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelManifest,
    pub vae: VaeManifest,
    pub embed: EmbedManifest,
    pub detection_dataset: PathBuf,
    pub golden: Option<Golden>,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub decode_file: String,
    pub prefill_file: String,
    pub extract_file: String,
    pub vocab: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub kv_elems: usize,
    pub state_elems: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct VaeManifest {
    pub file: String,
    pub batch: usize,
    pub n_features: usize,
    /// train-split normalization constants (baked into the artifact; also
    /// needed host-side to z-normalize reconstruction errors)
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct EmbedManifest {
    pub file: String,
    pub batch: usize,
    pub hash_dim: usize,
    pub embed_dim: usize,
}

/// Golden outputs pinned at AOT time (cross-language numeric check).
#[derive(Debug, Clone)]
pub struct Golden {
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    pub slot: usize,
    pub prefill_argmax: usize,
    pub prefill_logits_head: Vec<f32>,
    pub decode_token: i32,
    pub decode_argmax: usize,
    pub decode_logits_head: Vec<f32>,
}

fn req_usize(j: &Json, path: &[&str]) -> Result<usize> {
    j.at(path)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest missing {:?}", path))
}

fn req_str(j: &Json, path: &[&str]) -> Result<String> {
    Ok(j.at(path)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest missing {:?}", path))?
        .to_string())
}

impl Manifest {
    /// Locate the artifacts dir: `$ENOVA_ARTIFACTS`, `./artifacts`, or the
    /// crate-root artifacts when running under `cargo test`.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("ENOVA_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Whether the AOT artifacts are present (callers use this to fall
    /// back to artifact-free code paths, e.g. the gateway's sim engine).
    pub fn artifacts_exist() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let model = ModelManifest {
            decode_file: req_str(&j, &["model", "decode_file"])?,
            prefill_file: req_str(&j, &["model", "prefill_file"])?,
            extract_file: req_str(&j, &["model", "extract_file"])?,
            vocab: req_usize(&j, &["model", "vocab"])?,
            max_seq: req_usize(&j, &["model", "max_seq"])?,
            batch: req_usize(&j, &["model", "batch"])?,
            kv_elems: req_usize(&j, &["model", "kv_elems"])?,
            state_elems: req_usize(&j, &["model", "state_elems"])?,
            n_layers: req_usize(&j, &["model", "n_layers"])?,
            n_heads: req_usize(&j, &["model", "n_heads"])?,
            head_dim: req_usize(&j, &["model", "head_dim"])?,
            param_count: req_usize(&j, &["model", "param_count"])?,
        };
        if model.state_elems != model.kv_elems + model.batch * model.vocab {
            bail!("manifest state layout inconsistent");
        }
        let f64s = |path: [&str; 2]| -> Result<Vec<f64>> {
            Ok(j.at(&path)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest missing {path:?}"))?
                .iter()
                .filter_map(Json::as_f64)
                .collect())
        };
        let vae = VaeManifest {
            file: req_str(&j, &["vae", "file"])?,
            batch: req_usize(&j, &["vae", "batch"])?,
            n_features: req_usize(&j, &["vae", "n_features"])?,
            mean: f64s(["vae", "mean"])?,
            std: f64s(["vae", "std"])?,
        };
        let embed = EmbedManifest {
            file: req_str(&j, &["embed", "file"])?,
            batch: req_usize(&j, &["embed", "batch"])?,
            hash_dim: req_usize(&j, &["embed", "hash_dim"])?,
            embed_dim: req_usize(&j, &["embed", "embed_dim"])?,
        };
        let golden = j.get("golden").map(|g| -> Result<Golden> {
            let ints = |key: &str| -> Result<Vec<i32>> {
                Ok(g.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("golden missing {key}"))?
                    .iter()
                    .filter_map(Json::as_f64)
                    .map(|x| x as i32)
                    .collect())
            };
            let floats = |key: &str| -> Result<Vec<f32>> {
                Ok(g.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("golden missing {key}"))?
                    .iter()
                    .filter_map(Json::as_f64)
                    .map(|x| x as f32)
                    .collect())
            };
            Ok(Golden {
                prompt: ints("prompt")?,
                prompt_len: req_usize(g, &["prompt_len"])?,
                slot: req_usize(g, &["slot"])?,
                prefill_argmax: req_usize(g, &["prefill_argmax"])?,
                prefill_logits_head: floats("prefill_logits_head")?,
                decode_token: req_usize(g, &["decode_token"])? as i32,
                decode_argmax: req_usize(g, &["decode_argmax"])?,
                decode_logits_head: floats("decode_logits_head")?,
            })
        });
        let golden = match golden {
            Some(g) => Some(g?),
            None => None,
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            detection_dataset: dir.join(req_str(&j, &["detection_dataset"])?),
            model,
            vae,
            embed,
            golden,
        })
    }
}

/// Shared PJRT CPU client + executable loader.
pub struct PjRt {
    pub client: xla::PjRtClient,
}

impl PjRt {
    pub fn cpu() -> Result<Arc<PjRt>> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Arc::new(PjRt { client }))
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("buffer_from_host_buffer: {e:?}"))
    }

    pub fn buffer_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("buffer_from_host_buffer: {e:?}"))
    }
}

/// Execute with buffer args, expecting a single array output buffer.
pub fn execute_b1(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<xla::PjRtBuffer> {
    let mut out = exe
        .execute_b(args)
        .map_err(|e| anyhow!("execute_b: {e:?}"))?;
    let mut replica = out
        .pop()
        .ok_or_else(|| anyhow!("no execution results"))?;
    replica
        .pop()
        .ok_or_else(|| anyhow!("no output buffer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::artifacts_exist()
    }

    #[test]
    fn manifest_parses_and_is_consistent() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert!(m.model.batch >= 4);
        assert_eq!(
            m.model.state_elems,
            m.model.kv_elems + m.model.batch * m.model.vocab
        );
        assert!(m.detection_dataset.exists());
        assert!(m.golden.is_some(), "golden outputs missing from manifest");
    }

    #[test]
    fn client_compiles_all_artifacts() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let rt = PjRt::cpu().unwrap();
        for f in [&m.model.decode_file, &m.model.prefill_file, &m.model.extract_file, &m.vae.file, &m.embed.file] {
            rt.compile_file(&m.dir.join(f)).unwrap();
        }
    }
}
