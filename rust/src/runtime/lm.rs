//! The served language model: state-carry prefill/decode over PJRT.
//!
//! `LmRuntime` owns the two compiled programs and a device-resident state
//! buffer `[KV ‖ logits]`. The engine drives it slot-wise:
//!
//! ```text
//! prefill(prompt, slot)  — fills slot's KV, logits[slot] = first-token logits
//! decode(tokens, lens)   — one step for the whole running batch
//! logits(slot)           — host copy of one row of the logits tail
//! ```
//!
//! Two execution modes, switchable for the perf study (§Perf):
//! * **chained** (default): state stays a `PjRtBuffer`; each call feeds the
//!   previous output straight back via `execute_b`, and `logits()` reads
//!   only `V` floats at an offset.
//! * **host-roundtrip**: state crosses the host on every call (the naive
//!   baseline the perf pass measures against).

use super::{execute_b1, Manifest, ModelManifest, PjRt};
use anyhow::{anyhow, bail, Result};
use std::path::Path;
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    Chained,
    HostRoundtrip,
}

pub struct LmRuntime {
    rt: Arc<PjRt>,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_exe: xla::PjRtLoadedExecutable,
    /// `state[:B*V].reshape(B,V)` — the CPU PJRT plugin lacks
    /// `CopyRawToHost`, so logits readback runs this tiny program against
    /// the device-resident state and materializes only its B×V output.
    extract_exe: xla::PjRtLoadedExecutable,
    pub spec: ModelManifest,
    pub mode: ExecMode,
    state: StateBuf,
    /// decode steps executed (for perf accounting)
    pub steps: u64,
}

enum StateBuf {
    Device(xla::PjRtBuffer),
    Host(Vec<f32>),
}

impl LmRuntime {
    pub fn load(rt: Arc<PjRt>, manifest: &Manifest, mode: ExecMode) -> Result<LmRuntime> {
        let decode_exe = rt.compile_file(&manifest.dir.join(&manifest.model.decode_file))?;
        let prefill_exe = rt.compile_file(&manifest.dir.join(&manifest.model.prefill_file))?;
        let extract_exe = rt.compile_file(&manifest.dir.join(&manifest.model.extract_file))?;
        let spec = manifest.model.clone();
        let state = Self::fresh_state(&rt, &spec, mode)?;
        Ok(LmRuntime {
            rt,
            decode_exe,
            prefill_exe,
            extract_exe,
            spec,
            mode,
            state,
            steps: 0,
        })
    }

    pub fn load_default(dir: &Path, mode: ExecMode) -> Result<LmRuntime> {
        let manifest = Manifest::load(dir)?;
        let rt = PjRt::cpu()?;
        Self::load(rt, &manifest, mode)
    }

    fn fresh_state(rt: &PjRt, spec: &ModelManifest, mode: ExecMode) -> Result<StateBuf> {
        let zeros = vec![0.0f32; spec.state_elems];
        Ok(match mode {
            ExecMode::Chained => StateBuf::Device(rt.buffer_f32(&zeros, &[spec.state_elems])?),
            ExecMode::HostRoundtrip => StateBuf::Host(zeros),
        })
    }

    /// Reset all KV/logits state (e.g. between benchmark runs).
    pub fn reset(&mut self) -> Result<()> {
        self.state = Self::fresh_state(&self.rt, &self.spec, self.mode)?;
        Ok(())
    }

    /// Prefill `prompt` (≤ max_seq tokens) into batch slot `slot`.
    pub fn prefill(&mut self, prompt: &[i32], slot: usize) -> Result<()> {
        let s = self.spec.max_seq;
        if prompt.is_empty() || prompt.len() > s {
            bail!("prompt length {} out of range 1..={s}", prompt.len());
        }
        if slot >= self.spec.batch {
            bail!("slot {slot} out of range");
        }
        let mut padded = vec![0i32; s];
        padded[..prompt.len()].copy_from_slice(prompt);
        let tokens = self.rt.buffer_i32(&padded, &[s])?;
        let plen = self.rt.buffer_i32(&[prompt.len() as i32], &[])?;
        let slot_b = self.rt.buffer_i32(&[slot as i32], &[])?;
        run_step(
            &self.rt,
            &self.spec,
            &mut self.state,
            &self.prefill_exe,
            &[&tokens, &plen, &slot_b],
        )
    }

    /// One decode step for the whole batch. `seq_lens[b] <= 0` marks slot b
    /// inactive.
    pub fn decode(&mut self, tokens: &[i32], seq_lens: &[i32]) -> Result<()> {
        if tokens.len() != self.spec.batch || seq_lens.len() != self.spec.batch {
            bail!("decode arity mismatch");
        }
        let t = self.rt.buffer_i32(tokens, &[self.spec.batch])?;
        let l = self.rt.buffer_i32(seq_lens, &[self.spec.batch])?;
        self.steps += 1;
        run_step(&self.rt, &self.spec, &mut self.state, &self.decode_exe, &[&t, &l])
    }

    /// Copy one slot's logits row (`V` floats) to the host.
    pub fn logits(&self, slot: usize) -> Result<Vec<f32>> {
        let v = self.spec.vocab;
        let all = self.all_logits()?;
        Ok(all[slot * v..(slot + 1) * v].to_vec())
    }

    /// All logits rows at once (`B×V`), for batched sampling.
    ///
    /// Chained mode runs the `extract_logits` program against the
    /// device-resident state: only B×V floats are materialized on the
    /// host, the multi-megabyte KV region never moves.
    pub fn all_logits(&self) -> Result<Vec<f32>> {
        let n = self.spec.batch * self.spec.vocab;
        match &self.state {
            StateBuf::Device(buf) => {
                let out = execute_b1(&self.extract_exe, &[buf])?;
                let lit = out
                    .to_literal_sync()
                    .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
            }
            StateBuf::Host(host) => Ok(host[..n].to_vec()),
        }
    }
}

/// Advance the state by one program invocation (free function so callers
/// can borrow `state` mutably and the executable immutably from the same
/// struct).
fn run_step(
    rt: &PjRt,
    spec: &ModelManifest,
    state: &mut StateBuf,
    exe: &xla::PjRtLoadedExecutable,
    extra: &[&xla::PjRtBuffer],
) -> Result<()> {
    match state {
        StateBuf::Device(buf) => {
            let mut args: Vec<&xla::PjRtBuffer> = vec![buf];
            args.extend_from_slice(extra);
            let out = execute_b1(exe, &args)?;
            *state = StateBuf::Device(out);
        }
        StateBuf::Host(host) => {
            // naive mode: upload, run, download everything
            let up = rt.buffer_f32(host, &[spec.state_elems])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&up];
            args.extend_from_slice(extra);
            let out = execute_b1(exe, &args)?;
            let lit = out
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            *state = StateBuf::Host(v);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    // covered by rust/tests/runtime_golden.rs (needs artifacts on disk)
}
