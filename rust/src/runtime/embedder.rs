//! Request-text embedder runtime: FNV-1a n-gram feature hashing on the
//! rust side (mirrors `python/compile/embedder.py::hash_ngrams` exactly —
//! pinned by tests on both sides) + the compiled projection artifact.

use super::{execute_b1, EmbedManifest, Manifest, PjRt};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// FNV-1a hash of char 3-grams → l1-normalized count vector.
pub fn hash_ngrams(text: &str, hash_dim: usize) -> Vec<f32> {
    const N: usize = 3;
    let mut v = vec![0.0f32; hash_dim];
    let lower = text.to_lowercase();
    let mut data = lower.into_bytes();
    while data.len() < N {
        data.push(b' ');
    }
    for win in data.windows(N) {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for &b in win {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        v[(h % hash_dim as u64) as usize] += 1.0;
    }
    let s: f32 = v.iter().sum();
    if s > 0.0 {
        for x in v.iter_mut() {
            *x /= s;
        }
    }
    v
}

pub struct EmbedRuntime {
    rt: Arc<PjRt>,
    exe: xla::PjRtLoadedExecutable,
    pub spec: EmbedManifest,
}

impl EmbedRuntime {
    pub fn load(rt: Arc<PjRt>, manifest: &Manifest) -> Result<EmbedRuntime> {
        let exe = rt.compile_file(&manifest.dir.join(&manifest.embed.file))?;
        Ok(EmbedRuntime {
            rt,
            exe,
            spec: manifest.embed.clone(),
        })
    }

    /// Embed a batch of request texts into unit vectors.
    pub fn embed(&self, texts: &[&str]) -> Result<Vec<Vec<f64>>> {
        let (b, h, e) = (self.spec.batch, self.spec.hash_dim, self.spec.embed_dim);
        let mut out = Vec::with_capacity(texts.len());
        let mut chunk = vec![0.0f32; b * h];
        let mut i = 0;
        while i < texts.len() {
            let take = (texts.len() - i).min(b);
            chunk.fill(0.0);
            for (r, text) in texts[i..i + take].iter().enumerate() {
                let feats = hash_ngrams(text, h);
                chunk[r * h..(r + 1) * h].copy_from_slice(&feats);
            }
            let input = self.rt.buffer_f32(&chunk, &[b, h])?;
            let result = execute_b1(&self.exe, &[&input])?;
            let lit = result
                .to_literal_sync()
                .map_err(|e2| anyhow!("to_literal: {e2:?}"))?;
            let vals = lit
                .to_vec::<f32>()
                .map_err(|e2| anyhow!("to_vec: {e2:?}"))?;
            for r in 0..take {
                out.push(vals[r * e..(r + 1) * e].iter().map(|&x| x as f64).collect());
            }
            i += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_matches_python_pin() {
        // python/tests/test_embedder.py pins FNV-1a("abc") % 1024 == 843
        let v = hash_ngrams("abc", 1024);
        let nonzero: Vec<usize> = v
            .iter()
            .enumerate()
            .filter(|(_, &x)| x > 0.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(nonzero, vec![843]);
        assert!((v[843] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn hash_l1_normalized_and_deterministic() {
        let a = hash_ngrams("write a python function to sort a list", 1024);
        let b = hash_ngrams("write a python function to sort a list", 1024);
        assert_eq!(a, b);
        let s: f32 = a.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn short_text_padded() {
        let v = hash_ngrams("a", 64);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }
}
