//! VAE scorer runtime: runs the trained semi-supervised VAE artifact.
//!
//! The lowered program maps a batch of raw metric rows `f32[B, F]` to
//! `f32[B, F+1]`: columns `[0, F)` are the de-normalized reconstruction,
//! column `F` is `KL(q(z|m) ‖ p(z))` — the anomaly score of §IV-B.
//! Normalization constants are baked into the artifact.

use super::{execute_b1, Manifest, PjRt, VaeManifest};
use anyhow::{anyhow, Result};
use std::sync::Arc;

#[derive(Debug, Clone, Copy)]
pub struct VaeScore {
    /// KL(q(z|m) ‖ p(z)) — the latent-divergence component of the ELBO
    pub kl: f64,
    /// z-normalized squared reconstruction error — the reconstruction-
    /// probability component of the ELBO (−log p(m|z) up to constants)
    pub recon_err: f64,
    /// mean(input − reconstruction) — the MD statistic deciding
    /// scale-up (positive: observed above normal) vs scale-down.
    pub mean_diff: f64,
}

pub struct VaeRuntime {
    rt: Arc<PjRt>,
    exe: xla::PjRtLoadedExecutable,
    pub spec: VaeManifest,
}

impl VaeRuntime {
    pub fn load(rt: Arc<PjRt>, manifest: &Manifest) -> Result<VaeRuntime> {
        let exe = rt.compile_file(&manifest.dir.join(&manifest.vae.file))?;
        Ok(VaeRuntime {
            rt,
            exe,
            spec: manifest.vae.clone(),
        })
    }

    /// Score a batch of metric rows (row-major `n × F`, any `n`).
    pub fn score(&self, rows: &[f64]) -> Result<Vec<VaeScore>> {
        let f = self.spec.n_features;
        assert_eq!(rows.len() % f, 0, "rows must be n×{f}");
        let n = rows.len() / f;
        let b = self.spec.batch;
        let mut out = Vec::with_capacity(n);
        let mut chunk = vec![0.0f32; b * f];
        let mut i = 0;
        while i < n {
            let take = (n - i).min(b);
            for (dst, src) in chunk
                .iter_mut()
                .zip(rows[i * f..(i + take) * f].iter())
            {
                *dst = *src as f32;
            }
            // pad the tail chunk by repeating the last row (scores ignored)
            for j in take * f..b * f {
                chunk[j] = chunk[j % (take * f).max(1)];
            }
            let input = self.rt.buffer_f32(&chunk, &[b, f])?;
            let result = execute_b1(&self.exe, &[&input])?;
            let lit = result
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let vals = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec: {e:?}"))?;
            for r in 0..take {
                let row = &vals[r * (f + 1)..(r + 1) * (f + 1)];
                let kl = row[f] as f64;
                let mut md = 0.0;
                let mut err = 0.0;
                for c in 0..f {
                    let diff = rows[(i + r) * f + c] - row[c] as f64;
                    md += diff;
                    let z = diff / self.spec.std[c].max(1e-9);
                    err += z * z;
                }
                out.push(VaeScore {
                    kl,
                    recon_err: err / f as f64,
                    mean_diff: md / f as f64,
                });
            }
            i += take;
        }
        Ok(out)
    }
}
