//! Request pool + weighted load balancer (the "LLM Load Balancer" layer of
//! Table I). Weights come from the configuration module (∝ per-replica
//! n_limit, §IV-A-4); dispatch picks the replica with the lowest
//! weight-normalized in-flight load (smooth weighted least-loaded), which
//! converges to weight-proportional splits under saturation while staying
//! responsive to transient imbalance.
//!
//! Reconfiguration ([`WeightedRouter::set_weights`], the autoscaler's
//! ingress-update path) preserves the live [`ReplicaHandle`] for every
//! replica id that survives: in-flight requests hold `Arc`s into the
//! router, so counters must not reset mid-flight.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[derive(Debug)]
pub struct ReplicaHandle {
    pub id: u64,
    /// routing weight as f64 bits — atomically updatable while requests
    /// are in flight
    weight_bits: AtomicU64,
    inflight: AtomicU64,
    dispatched: AtomicU64,
}

impl ReplicaHandle {
    fn new(id: u64, weight: f64) -> ReplicaHandle {
        ReplicaHandle {
            id,
            weight_bits: AtomicU64::new(weight.max(1e-9).to_bits()),
            inflight: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
        }
    }

    pub fn weight(&self) -> f64 {
        f64::from_bits(self.weight_bits.load(Ordering::Relaxed))
    }

    fn set_weight(&self, weight: f64) {
        self.weight_bits
            .store(weight.max(1e-9).to_bits(), Ordering::Relaxed);
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// Mark one in-flight request finished. Saturates at zero: a stale
    /// handle (replica removed and its id later reused) must never wrap a
    /// fresh counter to `u64::MAX`.
    pub fn complete(&self) {
        let _ = self
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }
}

#[derive(Debug, Default)]
pub struct WeightedRouter {
    replicas: Vec<Arc<ReplicaHandle>>,
}

impl WeightedRouter {
    pub fn new(weights: &[(u64, f64)]) -> WeightedRouter {
        WeightedRouter {
            replicas: weights
                .iter()
                .map(|&(id, weight)| Arc::new(ReplicaHandle::new(id, weight)))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Route one request; returns the chosen replica. Call
    /// [`WeightedRouter::complete`] when the request finishes.
    pub fn dispatch(&self) -> Option<Arc<ReplicaHandle>> {
        let chosen = self.replicas.iter().min_by(|a, b| {
            let la = (a.inflight() as f64 + 1.0) / a.weight();
            let lb = (b.inflight() as f64 + 1.0) / b.weight();
            la.total_cmp(&lb)
        })?;
        chosen.inflight.fetch_add(1, Ordering::Relaxed);
        chosen.dispatched.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(chosen))
    }

    pub fn complete(&self, handle: &ReplicaHandle) {
        handle.complete();
    }

    /// Replace the replica set after a reconfiguration (ingress update).
    /// Ids that survive keep their handle — and therefore their `inflight`
    /// and `dispatched` counters — so completions of requests dispatched
    /// before the update still land on the right counter. Duplicate ids in
    /// the new set are ignored after their first occurrence (two handles
    /// with one id would split the load accounting).
    pub fn set_weights(&mut self, weights: &[(u64, f64)]) {
        let mut old: BTreeMap<u64, Arc<ReplicaHandle>> =
            self.replicas.drain(..).map(|r| (r.id, r)).collect();
        let mut new: Vec<Arc<ReplicaHandle>> = Vec::with_capacity(weights.len());
        for &(id, weight) in weights {
            if new.iter().any(|r| r.id == id) {
                continue;
            }
            new.push(if let Some(existing) = old.remove(&id) {
                existing.set_weight(weight);
                existing
            } else {
                Arc::new(ReplicaHandle::new(id, weight))
            });
        }
        self.replicas = new;
    }

    pub fn replicas(&self) -> &[Arc<ReplicaHandle>] {
        &self.replicas
    }

    /// The current `(id, weight)` set — the base input for add-one /
    /// remove-one reconfigurations (replica hot-add and retirement).
    pub fn weights(&self) -> Vec<(u64, f64)> {
        self.replicas.iter().map(|r| (r.id, r.weight())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_proportionally_under_saturation() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 0.5)]);
        // steady state: dispatch without completing
        for _ in 0..300 {
            router.dispatch().unwrap();
        }
        let d0 = router.replicas()[0].dispatched() as f64;
        let d1 = router.replicas()[1].dispatched() as f64;
        let ratio = d0 / d1;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn prefers_idle_replica() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let h = router.dispatch().unwrap();
        // second dispatch must go to the other replica
        let h2 = router.dispatch().unwrap();
        assert_ne!(h.id, h2.id);
        router.complete(&h);
        router.complete(&h2);
        assert_eq!(router.replicas()[0].inflight(), 0);
    }

    #[test]
    fn empty_router() {
        let router = WeightedRouter::new(&[]);
        assert!(router.dispatch().is_none());
        assert!(router.is_empty());
    }

    #[test]
    fn set_weights_preserves_surviving_state() {
        let mut router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let h0 = router.dispatch().unwrap();
        let h1 = router.dispatch().unwrap();
        assert_ne!(h0.id, h1.id);

        // reconfigure mid-flight: replica 1 is removed, replica 2 is new,
        // replica 0 survives with a new weight
        router.set_weights(&[(0, 2.0), (2, 1.0)]);
        let r0 = router
            .replicas()
            .iter()
            .find(|r| r.id == 0)
            .unwrap()
            .clone();
        assert_eq!(r0.inflight(), 1, "surviving replica kept inflight");
        assert_eq!(r0.dispatched(), 1);
        assert!((r0.weight() - 2.0).abs() < 1e-12);

        // completing the pre-reconfig request lands on the same counter
        router.complete(if h0.id == 0 { &h0 } else { &h1 });
        assert_eq!(r0.inflight(), 0);

        // completing the removed replica's request must not touch live ones
        router.complete(if h0.id == 0 { &h1 } else { &h0 });
        let r2 = router.replicas().iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.inflight(), 0);
    }

    #[test]
    fn set_weights_ignores_duplicate_ids() {
        let mut router = WeightedRouter::new(&[(0, 1.0)]);
        let h = router.dispatch().unwrap();
        router.set_weights(&[(0, 1.0), (0, 3.0), (1, 1.0)]);
        assert_eq!(router.len(), 2, "duplicate id collapsed");
        let r0 = router.replicas().iter().find(|r| r.id == 0).unwrap();
        assert_eq!(r0.inflight(), 1, "first occurrence kept the live handle");
        router.complete(&h);
        assert_eq!(r0.inflight(), 0);
    }

    #[test]
    fn weights_roundtrip_through_set_weights() {
        let mut router = WeightedRouter::new(&[(0, 1.0), (3, 0.5)]);
        assert_eq!(router.weights(), vec![(0, 1.0), (3, 0.5)]);
        // add-one update built on weights(): existing handles survive
        let h = router.dispatch().unwrap();
        let mut w = router.weights();
        w.push((7, 2.0));
        router.set_weights(&w);
        assert_eq!(router.len(), 3);
        let kept = router.replicas().iter().find(|r| r.id == h.id).unwrap();
        assert_eq!(kept.inflight(), 1);
    }

    #[test]
    fn complete_saturates_at_zero() {
        let router = WeightedRouter::new(&[(0, 1.0)]);
        let h = router.dispatch().unwrap();
        router.complete(&h);
        router.complete(&h); // double-complete: no underflow
        assert_eq!(router.replicas()[0].inflight(), 0);
        assert!(router.dispatch().is_some());
    }
}
