//! Request pool + weighted load balancer (the "LLM Load Balancer" layer of
//! Table I). Weights come from the configuration module (∝ per-replica
//! n_limit, §IV-A-4); dispatch picks the replica with the lowest
//! weight-normalized in-flight load (smooth weighted least-loaded), which
//! converges to weight-proportional splits under saturation while staying
//! responsive to transient imbalance.
//!
//! Reconfiguration ([`WeightedRouter::set_weights`], the autoscaler's
//! ingress-update path) preserves the live [`ReplicaHandle`] for every
//! replica id that survives: in-flight requests hold `Arc`s into the
//! router, so counters must not reset mid-flight.
//!
//! Contention: the replica set lives behind an `Arc`, so the serving hot
//! path clones a [`RouterSnapshot`] out of the caller's `RwLock` (an
//! atomic refcount bump) and runs the least-loaded scan with no lock held
//! at all — reactor handler threads never serialize on routing state.
//! Handles are shared between the router and its snapshots, so in-flight
//! accounting stays live either way.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[derive(Debug)]
pub struct ReplicaHandle {
    pub id: u64,
    /// routing weight as f64 bits — atomically updatable while requests
    /// are in flight
    weight_bits: AtomicU64,
    inflight: AtomicU64,
    dispatched: AtomicU64,
}

impl ReplicaHandle {
    fn new(id: u64, weight: f64) -> ReplicaHandle {
        ReplicaHandle {
            id,
            weight_bits: AtomicU64::new(weight.max(1e-9).to_bits()),
            inflight: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
        }
    }

    pub fn weight(&self) -> f64 {
        f64::from_bits(self.weight_bits.load(Ordering::Relaxed))
    }

    fn set_weight(&self, weight: f64) {
        self.weight_bits
            .store(weight.max(1e-9).to_bits(), Ordering::Relaxed);
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// Mark one in-flight request finished. Saturates at zero: a stale
    /// handle (replica removed and its id later reused) must never wrap a
    /// fresh counter to `u64::MAX`.
    pub fn complete(&self) {
        let _ = self
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }
}

/// The least-loaded scan + counter updates, shared by the router and its
/// snapshots — one implementation of the load formula for every path.
fn pick(replicas: &[Arc<ReplicaHandle>], keep: impl Fn(u64) -> bool) -> Option<Arc<ReplicaHandle>> {
    let chosen = replicas
        .iter()
        .filter(|r| keep(r.id))
        .min_by(|a, b| {
            let la = (a.inflight() as f64 + 1.0) / a.weight();
            let lb = (b.inflight() as f64 + 1.0) / b.weight();
            la.total_cmp(&lb)
        })?;
    chosen.inflight.fetch_add(1, Ordering::Relaxed);
    chosen.dispatched.fetch_add(1, Ordering::Relaxed);
    Some(Arc::clone(chosen))
}

/// A lock-free view of the replica set, cloned out of the owning lock in
/// O(1) by [`WeightedRouter::snapshot`]. Dispatching through a snapshot
/// updates the *live* handles (they are shared with the router), so the
/// in-flight accounting is identical to dispatching through the router —
/// only the lock hold time changes.
#[derive(Debug, Clone)]
pub struct RouterSnapshot {
    replicas: Arc<Vec<Arc<ReplicaHandle>>>,
}

impl RouterSnapshot {
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn dispatch(&self) -> Option<Arc<ReplicaHandle>> {
        pick(&self.replicas, |_| true)
    }

    pub fn dispatch_where(&self, keep: impl Fn(u64) -> bool) -> Option<Arc<ReplicaHandle>> {
        pick(&self.replicas, keep)
    }
}

#[derive(Debug, Default)]
pub struct WeightedRouter {
    replicas: Arc<Vec<Arc<ReplicaHandle>>>,
}

impl WeightedRouter {
    pub fn new(weights: &[(u64, f64)]) -> WeightedRouter {
        WeightedRouter {
            replicas: Arc::new(
                weights
                    .iter()
                    .map(|&(id, weight)| Arc::new(ReplicaHandle::new(id, weight)))
                    .collect(),
            ),
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// O(1) handle for lock-free dispatch: clone this under the owning
    /// read lock, drop the lock, then dispatch against the snapshot.
    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            replicas: Arc::clone(&self.replicas),
        }
    }

    /// Route one request; returns the chosen replica. Call
    /// [`WeightedRouter::complete`] when the request finishes.
    pub fn dispatch(&self) -> Option<Arc<ReplicaHandle>> {
        pick(&self.replicas, |_| true)
    }

    /// [`WeightedRouter::dispatch`] restricted to the replicas `keep`
    /// admits — the retry path's building block (re-dispatch excluding
    /// nodes that already failed this request).
    pub fn dispatch_where(&self, keep: impl Fn(u64) -> bool) -> Option<Arc<ReplicaHandle>> {
        pick(&self.replicas, keep)
    }

    pub fn complete(&self, handle: &ReplicaHandle) {
        handle.complete();
    }

    /// Replace the replica set after a reconfiguration (ingress update).
    /// Ids that survive keep their handle — and therefore their `inflight`
    /// and `dispatched` counters — so completions of requests dispatched
    /// before the update still land on the right counter. Duplicate ids in
    /// the new set are ignored after their first occurrence (two handles
    /// with one id would split the load accounting). Snapshots taken
    /// before the update keep the old set (copy-on-write), which is the
    /// same race a pre-update dispatch always had.
    pub fn set_weights(&mut self, weights: &[(u64, f64)]) {
        let mut old: BTreeMap<u64, Arc<ReplicaHandle>> = self
            .replicas
            .iter()
            .map(|r| (r.id, Arc::clone(r)))
            .collect();
        let mut new: Vec<Arc<ReplicaHandle>> = Vec::with_capacity(weights.len());
        for &(id, weight) in weights {
            if new.iter().any(|r| r.id == id) {
                continue;
            }
            new.push(if let Some(existing) = old.remove(&id) {
                existing.set_weight(weight);
                existing
            } else {
                Arc::new(ReplicaHandle::new(id, weight))
            });
        }
        self.replicas = Arc::new(new);
    }

    pub fn replicas(&self) -> &[Arc<ReplicaHandle>] {
        &self.replicas
    }

    /// The current `(id, weight)` set — the base input for add-one /
    /// remove-one reconfigurations (replica hot-add and retirement).
    pub fn weights(&self) -> Vec<(u64, f64)> {
        self.replicas.iter().map(|r| (r.id, r.weight())).collect()
    }
}

/// Node-aware facade over [`WeightedRouter`] for the distributed serving
/// plane: the coordinator routes *across nodes* (string-identified, since
/// node ids are operator-chosen names), with the same smooth weighted
/// least-loaded policy and the same mid-flight counter preservation. Each
/// node gets a stable internal slot id for its whole registration
/// lifetime, so reconfigurations (health flips, weight updates from new
/// replica counts) keep the in-flight accounting of surviving nodes.
#[derive(Debug, Default)]
pub struct NodeRouter {
    inner: WeightedRouter,
    /// node id -> stable slot; entries persist across deroutes so a node
    /// that flaps unhealthy/healthy keeps its slot (and its counters,
    /// while requests still hold its handle). Behind `Arc`s so a
    /// [`NodeRouterSnapshot`] is three refcount bumps, not a map clone.
    slots: Arc<BTreeMap<String, u64>>,
    names: Arc<BTreeMap<u64, String>>,
    next_slot: u64,
}

/// Lock-free dispatch view of a [`NodeRouter`] — the coordinator's proxy
/// loop clones one per attempt under a brief read lock and routes without
/// serializing against heartbeat-driven router rebuilds.
#[derive(Debug, Clone)]
pub struct NodeRouterSnapshot {
    inner: RouterSnapshot,
    slots: Arc<BTreeMap<String, u64>>,
    names: Arc<BTreeMap<u64, String>>,
}

impl NodeRouterSnapshot {
    pub fn dispatch(&self) -> Option<(String, Arc<ReplicaHandle>)> {
        let handle = self.inner.dispatch()?;
        let name = self.names.get(&handle.id)?.clone();
        Some((name, handle))
    }

    pub fn dispatch_excluding(&self, exclude: &[String]) -> Option<(String, Arc<ReplicaHandle>)> {
        let excluded_slots: Vec<u64> = exclude
            .iter()
            .filter_map(|n| self.slots.get(n).copied())
            .collect();
        let handle = self
            .inner
            .dispatch_where(|id| !excluded_slots.contains(&id))?;
        let name = self.names.get(&handle.id)?.clone();
        Some((name, handle))
    }

    /// Least-loaded dispatch restricted to `preferred` nodes (minus
    /// `exclude`), falling back to the full set when no preferred node is
    /// routable — a preference, never a filter, so SLO-tier affinity can
    /// steer traffic without ever stranding a request. Used by the
    /// coordinator to keep latency-tier tenants off batch-heavy nodes.
    pub fn dispatch_preferring(
        &self,
        preferred: &[String],
        exclude: &[String],
    ) -> Option<(String, Arc<ReplicaHandle>)> {
        let preferred_slots: Vec<u64> = preferred
            .iter()
            .filter(|n| !exclude.contains(n))
            .filter_map(|n| self.slots.get(n).copied())
            .collect();
        if !preferred_slots.is_empty() {
            if let Some(handle) = self.inner.dispatch_where(|id| preferred_slots.contains(&id)) {
                if let Some(name) = self.names.get(&handle.id).cloned() {
                    return Some((name, handle));
                }
            }
        }
        self.dispatch_excluding(exclude)
    }
}

impl NodeRouter {
    pub fn new() -> NodeRouter {
        NodeRouter::default()
    }

    /// Number of currently routable nodes.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// O(1) handle for lock-free dispatch (see [`NodeRouterSnapshot`]).
    pub fn snapshot(&self) -> NodeRouterSnapshot {
        NodeRouterSnapshot {
            inner: self.inner.snapshot(),
            slots: Arc::clone(&self.slots),
            names: Arc::clone(&self.names),
        }
    }

    /// Replace the routable node set. Weights are typically the node's
    /// live replica count, so least-loaded dispatch converges to
    /// replica-proportional splits; nodes absent from `nodes` (unhealthy,
    /// departed) stop receiving traffic but keep their slot for a later
    /// return.
    pub fn set_nodes(&mut self, nodes: &[(String, f64)]) {
        // copy-on-write: outstanding snapshots keep the maps they saw
        let slots = Arc::make_mut(&mut self.slots);
        let names = Arc::make_mut(&mut self.names);
        let mut next_slot = self.next_slot;
        let weights: Vec<(u64, f64)> = nodes
            .iter()
            .map(|(name, weight)| {
                let slot = match slots.get(name) {
                    Some(&s) => s,
                    None => {
                        let s = next_slot;
                        next_slot += 1;
                        slots.insert(name.clone(), s);
                        names.insert(s, name.clone());
                        s
                    }
                };
                (slot, *weight)
            })
            .collect();
        self.next_slot = next_slot;
        self.inner.set_weights(&weights);
    }

    /// Route one request: the routable node with the lowest
    /// weight-normalized in-flight load. The caller must call
    /// [`ReplicaHandle::complete`] on the handle when the request
    /// finishes (or is abandoned).
    pub fn dispatch(&self) -> Option<(String, Arc<ReplicaHandle>)> {
        let handle = self.inner.dispatch()?;
        let name = self.names.get(&handle.id)?.clone();
        Some((name, handle))
    }

    /// Like [`NodeRouter::dispatch`] but never picks a node in `exclude` —
    /// the retry path after a node failed an attempt for this request.
    pub fn dispatch_excluding(&self, exclude: &[String]) -> Option<(String, Arc<ReplicaHandle>)> {
        let excluded_slots: Vec<u64> = exclude
            .iter()
            .filter_map(|n| self.slots.get(n).copied())
            .collect();
        let handle = self
            .inner
            .dispatch_where(|id| !excluded_slots.contains(&id))?;
        let name = self.names.get(&handle.id)?.clone();
        Some((name, handle))
    }

    /// In-flight count of one node (0 when unknown or derouted with no
    /// outstanding requests).
    pub fn inflight_of(&self, node: &str) -> u64 {
        let Some(slot) = self.slots.get(node) else {
            return 0;
        };
        self.inner
            .replicas()
            .iter()
            .find(|r| r.id == *slot)
            .map(|r| r.inflight())
            .unwrap_or(0)
    }

    /// Currently routable node names, ascending by slot age.
    pub fn routable(&self) -> Vec<String> {
        self.inner
            .replicas()
            .iter()
            .filter_map(|r| self.names.get(&r.id).cloned())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_proportionally_under_saturation() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 0.5)]);
        // steady state: dispatch without completing
        for _ in 0..300 {
            router.dispatch().unwrap();
        }
        let d0 = router.replicas()[0].dispatched() as f64;
        let d1 = router.replicas()[1].dispatched() as f64;
        let ratio = d0 / d1;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn prefers_idle_replica() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let h = router.dispatch().unwrap();
        // second dispatch must go to the other replica
        let h2 = router.dispatch().unwrap();
        assert_ne!(h.id, h2.id);
        router.complete(&h);
        router.complete(&h2);
        assert_eq!(router.replicas()[0].inflight(), 0);
    }

    #[test]
    fn empty_router() {
        let router = WeightedRouter::new(&[]);
        assert!(router.dispatch().is_none());
        assert!(router.is_empty());
    }

    #[test]
    fn set_weights_preserves_surviving_state() {
        let mut router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let h0 = router.dispatch().unwrap();
        let h1 = router.dispatch().unwrap();
        assert_ne!(h0.id, h1.id);

        // reconfigure mid-flight: replica 1 is removed, replica 2 is new,
        // replica 0 survives with a new weight
        router.set_weights(&[(0, 2.0), (2, 1.0)]);
        let r0 = router
            .replicas()
            .iter()
            .find(|r| r.id == 0)
            .unwrap()
            .clone();
        assert_eq!(r0.inflight(), 1, "surviving replica kept inflight");
        assert_eq!(r0.dispatched(), 1);
        assert!((r0.weight() - 2.0).abs() < 1e-12);

        // completing the pre-reconfig request lands on the same counter
        router.complete(if h0.id == 0 { &h0 } else { &h1 });
        assert_eq!(r0.inflight(), 0);

        // completing the removed replica's request must not touch live ones
        router.complete(if h0.id == 0 { &h1 } else { &h0 });
        let r2 = router.replicas().iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.inflight(), 0);
    }

    #[test]
    fn set_weights_ignores_duplicate_ids() {
        let mut router = WeightedRouter::new(&[(0, 1.0)]);
        let h = router.dispatch().unwrap();
        router.set_weights(&[(0, 1.0), (0, 3.0), (1, 1.0)]);
        assert_eq!(router.len(), 2, "duplicate id collapsed");
        let r0 = router.replicas().iter().find(|r| r.id == 0).unwrap();
        assert_eq!(r0.inflight(), 1, "first occurrence kept the live handle");
        router.complete(&h);
        assert_eq!(r0.inflight(), 0);
    }

    #[test]
    fn weights_roundtrip_through_set_weights() {
        let mut router = WeightedRouter::new(&[(0, 1.0), (3, 0.5)]);
        assert_eq!(router.weights(), vec![(0, 1.0), (3, 0.5)]);
        // add-one update built on weights(): existing handles survive
        let h = router.dispatch().unwrap();
        let mut w = router.weights();
        w.push((7, 2.0));
        router.set_weights(&w);
        assert_eq!(router.len(), 3);
        let kept = router.replicas().iter().find(|r| r.id == h.id).unwrap();
        assert_eq!(kept.inflight(), 1);
    }

    #[test]
    fn complete_saturates_at_zero() {
        let router = WeightedRouter::new(&[(0, 1.0)]);
        let h = router.dispatch().unwrap();
        router.complete(&h);
        router.complete(&h); // double-complete: no underflow
        assert_eq!(router.replicas()[0].inflight(), 0);
        assert!(router.dispatch().is_some());
    }

    /// Regression for the traced retry path: the proxy loop does one
    /// dispatch + one complete per *attempt*, with span recording in
    /// between. `dispatched` must count attempts monotonically (exactly
    /// one bump per dispatch, none from tracing) and every attempt's
    /// complete must rebalance `inflight` to zero — no double count when
    /// a request takes several attempts.
    #[test]
    fn retry_attempts_keep_counters_balanced() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let total_dispatched =
            |r: &WeightedRouter| r.replicas().iter().map(|h| h.dispatched()).sum::<u64>();

        // attempt 1 fails: span recorded, handle completed, id excluded
        let first = router.dispatch().unwrap();
        router.complete(&first);
        assert_eq!(total_dispatched(&router), 1);

        // attempt 2 re-dispatches excluding the failed replica
        let second = router.dispatch_where(|id| id != first.id).unwrap();
        assert_ne!(second.id, first.id, "retry avoided the failed replica");
        router.complete(&second);
        assert_eq!(total_dispatched(&router), 2, "one bump per attempt");
        for r in router.replicas() {
            assert_eq!(r.inflight(), 0, "every attempt completed exactly once");
        }

        // the counter is monotonic: later traffic only moves it forward
        let before = total_dispatched(&router);
        router.complete(&first); // stale double-complete saturates...
        let h = router.dispatch().unwrap();
        router.complete(&h);
        assert_eq!(total_dispatched(&router), before + 1, "...and never rewinds");
    }

    #[test]
    fn snapshot_dispatch_is_live_and_survives_reconfigure() {
        let mut router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let snap = router.snapshot();
        let h = snap.dispatch().unwrap();
        // handles are shared: the router sees the snapshot's dispatch
        let inflight: u64 = router.replicas().iter().map(|r| r.inflight()).sum();
        assert_eq!(inflight, 1);
        // reconfigure while the snapshot is out: copy-on-write keeps the
        // snapshot's set intact (same race a pre-update dispatch had)
        router.set_weights(&[(7, 1.0)]);
        assert_eq!(snap.len(), 2, "snapshot kept the pre-update set");
        assert!(snap.dispatch().is_some());
        assert_eq!(router.len(), 1);
        router.complete(&h);

        let mut nr = NodeRouter::new();
        nr.set_nodes(&[("a".to_string(), 1.0), ("b".to_string(), 1.0)]);
        let nsnap = nr.snapshot();
        let (name, nh) = nsnap.dispatch_excluding(&["a".to_string()]).unwrap();
        assert_eq!(name, "b");
        assert_eq!(nr.inflight_of("b"), 1, "live counters through the snapshot");
        nh.complete();
        assert_eq!(nr.inflight_of("b"), 0);
    }

    fn node_router(nodes: &[(&str, f64)]) -> NodeRouter {
        let mut r = NodeRouter::new();
        r.set_nodes(
            &nodes
                .iter()
                .map(|(n, w)| (n.to_string(), *w))
                .collect::<Vec<_>>(),
        );
        r
    }

    #[test]
    fn node_router_dispatches_least_loaded_by_name() {
        let r = node_router(&[("node-a", 1.0), ("node-b", 1.0)]);
        let (first, h1) = r.dispatch().unwrap();
        let (second, h2) = r.dispatch().unwrap();
        assert_ne!(first, second, "idle node preferred");
        h1.complete();
        h2.complete();
        assert_eq!(r.inflight_of("node-a"), 0);
        assert_eq!(r.inflight_of("node-b"), 0);
        assert_eq!(r.inflight_of("node-unknown"), 0);
    }

    #[test]
    fn node_router_update_preserves_surviving_inflight() {
        let mut r = node_router(&[("node-a", 1.0), ("node-b", 1.0)]);
        let (name, h) = r.dispatch().unwrap();
        // reconfigure: the other node leaves, the survivor is re-weighted
        let survivor = name.clone();
        r.set_nodes(&[(survivor.clone(), 3.0)]);
        assert_eq!(r.len(), 1);
        assert_eq!(r.inflight_of(&survivor), 1, "counter survived the update");
        h.complete();
        assert_eq!(r.inflight_of(&survivor), 0);
        // and a flap back in reuses the old slot (counters intact)
        r.set_nodes(&[(survivor.clone(), 1.0), ("node-c".into(), 1.0)]);
        assert_eq!(r.routable().len(), 2);
    }

    #[test]
    fn node_router_weight_proportional_under_saturation() {
        let r = node_router(&[("big", 2.0), ("small", 1.0)]);
        for _ in 0..300 {
            r.dispatch().unwrap();
        }
        let big = r.inflight_of("big") as f64;
        let small = r.inflight_of("small") as f64;
        let ratio = big / small;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn snapshot_preferring_steers_but_never_strands() {
        let r = node_router(&[("quiet", 1.0), ("batchy", 1.0)]);
        let snap = r.snapshot();
        // preference honored while the preferred node is routable
        for _ in 0..4 {
            let (name, h) = snap.dispatch_preferring(&["quiet".to_string()], &[]).unwrap();
            assert_eq!(name, "quiet");
            h.complete();
        }
        // preferred node excluded this attempt: fall back, don't strand
        let (name, h) = snap
            .dispatch_preferring(&["quiet".to_string()], &["quiet".to_string()])
            .unwrap();
        assert_eq!(name, "batchy");
        h.complete();
        // unknown preferred names fall back to the full set
        let (name, h) = snap.dispatch_preferring(&["ghost".to_string()], &[]).unwrap();
        assert!(name == "quiet" || name == "batchy");
        h.complete();
        // empty preference behaves exactly like dispatch_excluding
        assert!(snap.dispatch_preferring(&[], &[]).is_some());
    }

    #[test]
    fn node_router_excluding_skips_failed_nodes() {
        let r = node_router(&[("node-a", 1.0), ("node-b", 1.0)]);
        for _ in 0..8 {
            let (name, _h) = r.dispatch_excluding(&["node-a".to_string()]).unwrap();
            assert_eq!(name, "node-b");
        }
        // excluding every node yields None, not a panic
        assert!(r
            .dispatch_excluding(&["node-a".to_string(), "node-b".to_string()])
            .is_none());
        let empty = NodeRouter::new();
        assert!(empty.dispatch().is_none());
        assert!(empty.is_empty());
    }
}
