//! Request pool + weighted load balancer (the "LLM Load Balancer" layer of
//! Table I). Weights come from the configuration module (∝ per-replica
//! n_limit, §IV-A-4); dispatch picks the replica with the lowest
//! weight-normalized in-flight load (smooth weighted least-loaded), which
//! converges to weight-proportional splits under saturation while staying
//! responsive to transient imbalance.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[derive(Debug)]
pub struct ReplicaHandle {
    pub id: u64,
    pub weight: f64,
    inflight: AtomicU64,
    dispatched: AtomicU64,
}

impl ReplicaHandle {
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }
}

#[derive(Debug, Default)]
pub struct WeightedRouter {
    replicas: Vec<Arc<ReplicaHandle>>,
}

impl WeightedRouter {
    pub fn new(weights: &[(u64, f64)]) -> WeightedRouter {
        WeightedRouter {
            replicas: weights
                .iter()
                .map(|&(id, weight)| {
                    Arc::new(ReplicaHandle {
                        id,
                        weight: weight.max(1e-9),
                        inflight: AtomicU64::new(0),
                        dispatched: AtomicU64::new(0),
                    })
                })
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Route one request; returns the chosen replica. Call
    /// [`WeightedRouter::complete`] when the request finishes.
    pub fn dispatch(&self) -> Option<Arc<ReplicaHandle>> {
        let chosen = self.replicas.iter().min_by(|a, b| {
            let la = (a.inflight() as f64 + 1.0) / a.weight;
            let lb = (b.inflight() as f64 + 1.0) / b.weight;
            la.total_cmp(&lb)
        })?;
        chosen.inflight.fetch_add(1, Ordering::Relaxed);
        chosen.dispatched.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(chosen))
    }

    pub fn complete(&self, handle: &ReplicaHandle) {
        handle.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Replace weights after a reconfiguration (ingress update).
    pub fn set_weights(&mut self, weights: &[(u64, f64)]) {
        *self = WeightedRouter::new(weights);
    }

    pub fn replicas(&self) -> &[Arc<ReplicaHandle>] {
        &self.replicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_proportionally_under_saturation() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 0.5)]);
        // steady state: dispatch without completing
        for _ in 0..300 {
            router.dispatch().unwrap();
        }
        let d0 = router.replicas()[0].dispatched() as f64;
        let d1 = router.replicas()[1].dispatched() as f64;
        let ratio = d0 / d1;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn prefers_idle_replica() {
        let router = WeightedRouter::new(&[(0, 1.0), (1, 1.0)]);
        let h = router.dispatch().unwrap();
        // second dispatch must go to the other replica
        let h2 = router.dispatch().unwrap();
        assert_ne!(h.id, h2.id);
        router.complete(&h);
        router.complete(&h2);
        assert_eq!(router.replicas()[0].inflight(), 0);
    }

    #[test]
    fn empty_router() {
        let router = WeightedRouter::new(&[]);
        assert!(router.dispatch().is_none());
        assert!(router.is_empty());
    }
}
