//! Thread-pool + event-loop substrate (tokio is not in the offline crate
//! set; the request path is CPU-bound anyway, so a worker pool over mpsc
//! channels is the right shape).
//!
//! * [`ThreadPool`] — fixed-size pool executing boxed jobs; `scope`-less,
//!   jobs are `'static`. Used for parallel bench sweeps and the detection
//!   baseline training.
//! * [`EventLoop`] — single-consumer command loop with a shutdown signal;
//!   the serving engine and autoscaler run on these.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("enova-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    pub fn default_parallelism() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("worker alive");
    }

    /// Run `f` over every item, collecting results in input order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, R)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.spawn(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx.iter().take(n) {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("job finished")).collect()
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::Acquire) > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Cooperative shutdown signal shared across loops.
#[derive(Clone, Default)]
pub struct Shutdown(Arc<AtomicBool>);

impl Shutdown {
    pub fn new() -> Shutdown {
        Shutdown::default()
    }

    pub fn trigger(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_triggered(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Single-consumer command loop: submit `C`s from any thread, a dedicated
/// thread folds them into the handler until shutdown.
pub struct EventLoop<C: Send + 'static> {
    tx: Sender<C>,
    handle: Option<JoinHandle<()>>,
    shutdown: Shutdown,
}

impl<C: Send + 'static> EventLoop<C> {
    pub fn spawn<F>(name: &str, mut handler: F) -> EventLoop<C>
    where
        F: FnMut(C) + Send + 'static,
    {
        let (tx, rx): (Sender<C>, Receiver<C>) = channel();
        let shutdown = Shutdown::new();
        let sd = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    handler(cmd);
                    if sd.is_triggered() {
                        break;
                    }
                }
            })
            .expect("spawn event loop");
        EventLoop {
            tx,
            handle: Some(handle),
            shutdown,
        }
    }

    pub fn submit(&self, cmd: C) -> bool {
        self.tx.send(cmd).is_ok()
    }

    pub fn shutdown(&mut self) {
        self.shutdown.trigger();
    }
}

impl<C: Send + 'static> Drop for EventLoop<C> {
    fn drop(&mut self) {
        // Disconnect our sender (replace with a dummy) WITHOUT triggering
        // shutdown: the handler thread drains every queued command (mpsc
        // keeps buffered messages alive after senders drop) and then exits
        // when recv() reports disconnection.
        let (dummy_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn event_loop_processes_and_drops_cleanly() {
        let seen = Arc::new(AtomicU64::new(0));
        let s = Arc::clone(&seen);
        let ev: EventLoop<u64> = EventLoop::spawn("test", move |x| {
            s.fetch_add(x, Ordering::SeqCst);
        });
        for i in 1..=10 {
            assert!(ev.submit(i));
        }
        drop(ev); // join; all submitted commands must have been handled
        assert_eq!(seen.load(Ordering::SeqCst), 55);
    }
}
