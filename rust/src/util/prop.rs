//! In-tree property-testing harness (proptest is not in the offline crate
//! set). A `check` runs a property over N seeded random cases; on failure
//! it re-runs with a greedy shrink pass over the failing seed's generator
//! parameters and reports the minimal failing case it found.
//!
//! Usage:
//! ```ignore
//! prop::check("sorted stays sorted", 200, |g| {
//!     let mut v = g.vec_f64(0..64, -1e3..1e3);
//!     v.sort_by(f64::total_cmp);
//!     prop::assert_sorted(&v)
//! });
//! ```

use super::rng::Pcg64;

/// Generator facade handed to properties; wraps a seeded RNG with sizing.
pub struct Gen {
    pub rng: Pcg64,
    /// Size budget in [0,1]: shrink passes reduce it toward 0 so generated
    /// values get smaller/simpler.
    pub size: f64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + (((hi - lo) as f64) * self.size).ceil() as usize;
        self.rng.usize_in(lo, hi_scaled.max(lo + 1).min(hi))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let span = (hi - lo) * self.size.max(0.05);
        self.rng.uniform(lo, lo + span)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f64(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(0, max_len + 1);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, max_len: usize, lo: usize, hi: usize) -> Vec<usize> {
        let n = self.usize_in(0, max_len + 1);
        (0..n).map(|_| self.usize_in(lo, hi)).collect()
    }
}

/// Run `prop` over `cases` random cases. Panics with the failing seed and
/// the smallest failing size found by the shrink pass.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base_seed + case;
        let mut g = Gen {
            rng: Pcg64::new(seed),
            size: 1.0,
        };
        if let Err(msg) = prop(&mut g) {
            // shrink: retry same seed at smaller sizes, keep smallest failure
            let mut min_fail = (1.0, msg);
            let mut size = 0.5;
            while size > 0.02 {
                let mut g = Gen {
                    rng: Pcg64::new(seed),
                    size,
                };
                if let Err(m) = prop(&mut g) {
                    min_fail = (size, m);
                    size *= 0.5;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, shrunk size={:.3}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assertion helpers returning Result so properties compose with `?`.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    ensure(
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())),
        format!("{ctx}: {a} vs {b} (tol {tol})"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 50, |g| {
            let v = g.vec_f64(32, -10.0, 10.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            ensure(v == w, "mismatch")
        });
    }

    #[test]
    #[should_panic(expected = "property 'sum bound' failed")]
    fn failing_property_reports_seed() {
        check("sum bound", 50, |g| {
            let v = g.vec_f64(32, 0.0, 10.0);
            ensure(v.iter().sum::<f64>() < 20.0, "sum too big")
        });
    }

    #[test]
    fn ensure_close_tolerates() {
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
