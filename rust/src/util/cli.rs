//! Minimal CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Subcommands are handled by the caller peeling the first positional.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        Args::parse_known(argv, &[])
    }

    /// `bool_flags` lists option names that never take a value, resolving
    /// the `--verbose input.txt` ambiguity.
    pub fn parse_known<I: IntoIterator<Item = String>>(argv: I, bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn from_env_known(bool_flags: &[&str]) -> Args {
        Args::parse_known(std::env::args().skip(1), bool_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Insert a default for `--name` unless the command line already set
    /// it — the layering seam for [`crate::settings::EnovaConfig`]: file
    /// values become defaults, explicit flags always win.
    pub fn set_default(&mut self, name: &str, value: &str) {
        self.options
            .entry(name.to_string())
            .or_insert_with(|| value.to_string());
    }

    /// Set a boolean flag unless already present (file-layering seam;
    /// flags are additive, so this can only turn a flag on).
    pub fn set_default_flag(&mut self, name: &str) {
        if !self.flag(name) {
            self.flags.push(name.to_string());
        }
    }

    /// Pop the subcommand (first positional); returns "" if absent.
    pub fn subcommand(&mut self) -> String {
        if self.positional.is_empty() {
            String::new()
        } else {
            self.positional.remove(0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_known(s.split_whitespace().map(String::from), &["verbose", "dry-run"])
    }

    #[test]
    fn mixed_forms() {
        let mut a = parse("serve --replicas 2 --gpu=a100 --verbose input.txt");
        assert_eq!(a.subcommand(), "serve");
        assert_eq!(a.get("replicas"), Some("2"));
        assert_eq!(a.get("gpu"), Some("a100"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse("--rps 3.5 --steps 100");
        assert_eq!(a.get_f64("rps", 1.0), 3.5);
        assert_eq!(a.get_usize("steps", 5), 100);
        assert_eq!(a.get_usize("missing", 5), 5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--dry-run");
        assert!(a.flag("dry-run"));
    }
}
