//! Deterministic PRNG + sampling distributions.
//!
//! The offline crate set has no `rand`, so this is a from-scratch PCG64
//! (O'Neill's PCG-XSL-RR 128/64) plus the distributions the simulator and
//! workload generators need: uniform, normal (Box–Muller), log-normal,
//! exponential, Poisson (Knuth for small λ, PTRS-style for large),
//! Gumbel, and generalized Pareto. Every experiment seeds its own `Pcg64`
//! so runs are reproducible bit-for-bit.

#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            gauss_spare: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng
            .state
            .wrapping_add(seed as u128 ^ ((seed as u128) << 64));
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-replica streams).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -u.ln() / rate
    }

    /// Poisson sample. Knuth's product method for λ < 30, normal
    /// approximation with continuity correction beyond (adequate for
    /// arrival counts; error < 1% at λ ≥ 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_with(lambda, lambda.sqrt());
            x.max(0.0).round() as u64
        }
    }

    /// Standard Gumbel (location 0, scale 1).
    pub fn gumbel(&mut self) -> f64 {
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -(-u.ln()).ln()
    }

    /// Generalized Pareto with shape `xi`, scale `sigma`.
    pub fn gpd(&mut self, xi: f64, sigma: f64) -> f64 {
        let u = 1.0 - self.f64();
        if xi.abs() < 1e-9 {
            -sigma * u.ln()
        } else {
            sigma * (u.powf(-xi) - 1.0) / xi
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(2);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut rng = Pcg64::new(3);
        for &lambda in &[0.5, 5.0, 80.0] {
            let n = 20_000;
            let total: u64 = (0..n).map(|_| rng.poisson(lambda)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda.max(1.0),
                "λ={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(4);
        let n = 30_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut rng = Pcg64::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.gumbel()).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg64::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..50).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
