//! Minimal JSON parser + writer (serde is not in the offline crate set).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is handled for
//! the BMP). Used for `artifacts/manifest.json`, service config files and
//! bench output. Numbers parse as f64; helpers expose integer views.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["model", "batch"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line rendering with no insignificant whitespace — the wire
    /// format for SSE payloads and HTTP response bodies.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder: `obj([("a", Json::Num(1.0))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(
        items
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf-8")?;
                    out.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] found {:?}", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {:?}", other)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"model": {"batch": 8, "files": ["a.txt", "b.txt"], "ok": true}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let out = Json::Str("tab\t\"q\"".into()).to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("tab\t\"q\""));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(8.0).to_string_pretty(), "8");
        assert_eq!(Json::Num(0.5).to_string_pretty(), "0.5");
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let src = r#"{"a": [1, {"b": "x y\nz"}], "c": true}"#;
        let j = Json::parse(src).unwrap();
        let wire = j.to_string_compact();
        assert!(!wire.contains('\n'), "in-string newlines are escaped");
        assert_eq!(wire, r#"{"a":[1,{"b":"x y\nz"}],"c":true}"#);
        assert_eq!(Json::parse(&wire).unwrap(), j);
    }
}
