//! Tiny leveled logger (env_logger is not in the offline crate set).
//!
//! `ENOVA_LOG=debug|info|warn|error` selects the level (default `info`).
//! Thread-safe via a global atomic level + line-buffered stderr writes.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("ENOVA_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = init_from_env();
    }
    level as u8 >= cur
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{h:02}:{m:02}:{s:02} {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
    }
}
