//! Tiny leveled logger (env_logger is not in the offline crate set).
//!
//! `ENOVA_LOG=debug|info|warn|error` selects the level (default `info`).
//! Thread-safe via a global atomic level + line-buffered stderr writes.
//!
//! `--log-json` (or `ENOVA_LOG_JSON=1`) switches every line to a single
//! structured JSON object `{"ts":…,"level":…,"target":…,"msg":…}` so
//! trace IDs embedded in messages survive log shipping verbatim.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static JSON: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized, 0 = text, 1 = json

fn init_from_env() -> u8 {
    let lvl = match std::env::var("ENOVA_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Switch to structured JSON lines (the `--log-json` flag).
pub fn set_json(on: bool) {
    JSON.store(u8::from(on), Ordering::Relaxed);
}

pub fn json_enabled() -> bool {
    let mut cur = JSON.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = u8::from(matches!(
            std::env::var("ENOVA_LOG_JSON").as_deref(),
            Ok("1") | Ok("true")
        ));
        JSON.store(cur, Ordering::Relaxed);
    }
    cur == 1
}

/// Minimal JSON string escape (quotes, backslashes, control chars).
fn escape_json(input: &str) -> String {
    let mut out = String::with_capacity(input.len() + 2);
    for c in input.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = init_from_env();
    }
    level as u8 >= cur
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    if json_enabled() {
        let level_name = match level {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        };
        eprintln!(
            "{{\"ts\":{:.3},\"level\":\"{}\",\"target\":\"{}\",\"msg\":\"{}\"}}",
            now.as_secs_f64(),
            level_name,
            escape_json(target),
            escape_json(&msg.to_string())
        );
        return;
    }
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{h:02}:{m:02}:{s:02} {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(escape_json("plain"), "plain");
        assert_eq!(escape_json("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape_json("line\nbreak\ttab"), "line\\nbreak\\ttab");
        assert_eq!(escape_json("\u{1}"), "\\u0001");
    }

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
    }
}
