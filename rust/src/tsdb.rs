//! In-memory time-series store — the "monitoring system" storage layer of
//! §V (the paper uses Prometheus + a stream processor; one process here).
//!
//! Series are keyed by (metric, instance). Points are (t_seconds, value)
//! appended in time order; queries are windowed slices and per-minute
//! downsamples. A bounded retention cap keeps long simulations O(window).
//!
//! Points live in a `VecDeque`: retention trimming pops from the front in
//! O(1) instead of memmoving the whole buffer on every push once a series
//! reaches the cap (the old `Vec::drain(..1)` was O(n) per point).

use std::collections::{BTreeMap, VecDeque};

#[derive(Debug, Default, Clone)]
pub struct Series {
    pub points: VecDeque<(f64, f64)>,
}

impl Series {
    fn push(&mut self, t: f64, v: f64, retention: usize) {
        debug_assert!(
            self.points.back().map(|&(pt, _)| t >= pt).unwrap_or(true),
            "out-of-order append"
        );
        self.points.push_back((t, v));
        while self.points.len() > retention {
            self.points.pop_front();
        }
    }

    /// Values with t in [t0, t1).
    pub fn window(&self, t0: f64, t1: f64) -> Vec<f64> {
        let start = self.points.partition_point(|&(t, _)| t < t0);
        let end = self.points.partition_point(|&(t, _)| t < t1);
        self.points.range(start..end).map(|&(_, v)| v).collect()
    }

    pub fn last(&self) -> Option<f64> {
        self.points.back().map(|&(_, v)| v)
    }

    pub fn last_n(&self, n: usize) -> Vec<f64> {
        let start = self.points.len().saturating_sub(n);
        self.points.range(start..).map(|&(_, v)| v).collect()
    }

    /// Mean per fixed-size bucket (e.g. 60 s) over [t0, t1).
    pub fn downsample(&self, t0: f64, t1: f64, bucket: f64) -> Vec<f64> {
        let n = ((t1 - t0) / bucket).ceil() as usize;
        let mut sums = vec![0.0; n];
        let mut counts = vec![0usize; n];
        let start = self.points.partition_point(|&(t, _)| t < t0);
        for &(t, v) in self.points.range(start..) {
            if t >= t1 {
                break;
            }
            let idx = ((t - t0) / bucket) as usize;
            if idx < n {
                sums[idx] += v;
                counts[idx] += 1;
            }
        }
        sums.iter()
            .zip(&counts)
            .map(|(&s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
            .collect()
    }
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    pub metric: String,
    pub instance: String,
}

#[derive(Debug, Default)]
pub struct MetricStore {
    series: BTreeMap<SeriesKey, Series>,
    /// max points kept per series
    pub retention: usize,
}

impl MetricStore {
    pub fn new() -> MetricStore {
        MetricStore {
            series: BTreeMap::new(),
            retention: 1_000_000,
        }
    }

    pub fn push(&mut self, metric: &str, instance: &str, t: f64, v: f64) {
        let key = SeriesKey {
            metric: metric.to_string(),
            instance: instance.to_string(),
        };
        let retention = self.retention;
        self.series.entry(key).or_default().push(t, v, retention);
    }

    pub fn series(&self, metric: &str, instance: &str) -> Option<&Series> {
        self.series.get(&SeriesKey {
            metric: metric.to_string(),
            instance: instance.to_string(),
        })
    }

    pub fn window(&self, metric: &str, instance: &str, t0: f64, t1: f64) -> Vec<f64> {
        self.series(metric, instance)
            .map(|s| s.window(t0, t1))
            .unwrap_or_default()
    }

    /// Last `n` values of a series, oldest first; empty when the series
    /// does not exist. The forecaster's de-noised sampling path.
    pub fn tail(&self, metric: &str, instance: &str, n: usize) -> Vec<f64> {
        self.series(metric, instance)
            .map(|s| s.last_n(n))
            .unwrap_or_default()
    }

    pub fn instances(&self, metric: &str) -> Vec<String> {
        self.series
            .keys()
            .filter(|k| k.metric == metric)
            .map(|k| k.instance.clone())
            .collect()
    }

    /// Drop every series of an instance (all metrics). Used when a replica
    /// is retired so exports stop showing frozen gauges for dead workers.
    pub fn remove_instance(&mut self, instance: &str) {
        self.series.retain(|k, _| k.instance != instance);
    }

    pub fn export_csv(&self, metric: &str, instance: &str) -> String {
        let mut out = String::from("t,value\n");
        if let Some(s) = self.series(metric, instance) {
            for &(t, v) in &s.points {
                out.push_str(&format!("{t},{v}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_queries() {
        let mut store = MetricStore::new();
        for i in 0..100 {
            store.push("n_running", "r0", i as f64, i as f64 * 2.0);
        }
        let w = store.window("n_running", "r0", 10.0, 20.0);
        assert_eq!(w.len(), 10);
        assert_eq!(w[0], 20.0);
        assert!(store.window("n_running", "missing", 0.0, 10.0).is_empty());
    }

    #[test]
    fn downsample_buckets() {
        let mut s = Series::default();
        for i in 0..120 {
            s.push(i as f64, 1.0 + (i / 60) as f64, usize::MAX);
        }
        let d = s.downsample(0.0, 120.0, 60.0);
        assert_eq!(d, vec![1.0, 2.0]);
    }

    #[test]
    fn retention_caps_memory() {
        let mut store = MetricStore::new();
        store.retention = 50;
        for i in 0..200 {
            store.push("m", "i", i as f64, 0.0);
        }
        assert_eq!(store.series("m", "i").unwrap().points.len(), 50);
        // oldest points dropped, newest kept
        assert_eq!(store.series("m", "i").unwrap().points[0].0, 150.0);
    }

    #[test]
    fn window_after_retention_wraparound() {
        // the deque's ring buffer has wrapped many times by the end; binary
        // search + range must still see a logically contiguous series
        let mut store = MetricStore::new();
        store.retention = 64;
        for i in 0..1000 {
            store.push("m", "i", i as f64, i as f64);
        }
        let w = store.window("m", "i", 950.0, 960.0);
        assert_eq!(w.len(), 10);
        assert_eq!(w[0], 950.0);
        assert_eq!(store.series("m", "i").unwrap().last(), Some(999.0));
    }

    #[test]
    fn remove_instance_drops_all_its_series() {
        let mut store = MetricStore::new();
        store.push("n_running", "replica-0", 0.0, 1.0);
        store.push("n_pending", "replica-0", 0.0, 2.0);
        store.push("n_running", "replica-1", 0.0, 3.0);
        store.remove_instance("replica-0");
        assert!(store.series("n_running", "replica-0").is_none());
        assert!(store.series("n_pending", "replica-0").is_none());
        assert_eq!(store.series("n_running", "replica-1").unwrap().last(), Some(3.0));
        assert_eq!(store.instances("n_running"), vec!["replica-1"]);
    }

    #[test]
    fn tail_reads_newest_values_or_nothing() {
        let mut store = MetricStore::new();
        for i in 0..10 {
            store.push("n_arriving", "replica-0", i as f64, i as f64 * 3.0);
        }
        assert_eq!(store.tail("n_arriving", "replica-0", 3), vec![21.0, 24.0, 27.0]);
        assert_eq!(store.tail("n_arriving", "replica-0", 100).len(), 10);
        assert!(store.tail("n_arriving", "absent", 3).is_empty());
        assert!(store.tail("missing", "replica-0", 3).is_empty());
    }

    #[test]
    fn last_n_short_series() {
        let mut s = Series::default();
        s.push(0.0, 1.0, usize::MAX);
        assert_eq!(s.last_n(10), vec![1.0]);
        assert_eq!(s.last(), Some(1.0));
    }
}
