//! Short-horizon request-rate forecasting for proactive autoscaling.
//!
//! ENOVA's performance-detection loop (§IV-B) is purely reactive: it waits
//! for a z-score anomaly before acting, so a predictable diurnal ramp is
//! always chased with cold-start lag. This module closes that gap the way
//! SageServe-style systems do — forecast the arrival rate a few sampling
//! steps ahead and pre-provision capacity *before* the demand arrives:
//!
//! * [`Forecaster`] runs two online models over the sampled rate series:
//!   a seasonal-naive baseline (last season's value; plain naive without a
//!   season) and Holt / Holt-Winters exponential smoothing (double when no
//!   season is configured, triple additive when one is). Every observation
//!   also matures the predictions made `horizon` steps earlier, so each
//!   model carries a trailing weighted-MAPE at exactly the horizon the
//!   supervisor plans against, and [`Forecaster::forecast`] always answers
//!   with the currently-better model.
//! * [`replicas_for_rate`] turns a predicted rate into a replica target
//!   given per-replica service capacity and a safety headroom — the pure
//!   half of the supervisor's proactive planner.
//!
//! The error tracking is the fallback story: when the trailing error rises
//! over the configured budget ([`Forecaster::degraded`]), the supervisor
//! stands the proactive planner down and the reactive detector loop keeps
//! the gateway safe — a wrong forecast can cost money, but never
//! correctness.
//!
//! Everything is NaN-free by construction: non-finite observations are
//! ignored, forecasts of a degenerate (constant, even all-zero) window are
//! the constant itself, and rates are clamped non-negative.

use std::collections::{BTreeMap, VecDeque};

/// Which model produced (or would produce) a forecast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// value one season ago (last value when no season is configured)
    SeasonalNaive,
    /// Holt double smoothing, or Holt-Winters additive triple smoothing
    /// once a full season has been observed
    Smoothing,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::SeasonalNaive => "seasonal_naive",
            Method::Smoothing => "smoothing",
        }
    }
}

#[derive(Debug, Clone)]
pub struct ForecastConfig {
    /// steps ahead the planner asks about; errors are tracked at exactly
    /// this horizon
    pub horizon: usize,
    /// season length in samples; 0 disables the seasonal components
    pub season: usize,
    /// level smoothing factor (0, 1]
    pub alpha: f64,
    /// trend smoothing factor (0, 1]
    pub beta: f64,
    /// seasonal smoothing factor (0, 1]
    pub gamma: f64,
    /// matured prediction errors kept per model
    pub err_window: usize,
    /// observations required before any forecast is answered
    pub min_history: usize,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        ForecastConfig {
            horizon: 5,
            season: 0,
            alpha: 0.35,
            beta: 0.15,
            gamma: 0.25,
            err_window: 120,
            min_history: 5,
        }
    }
}

/// Holt / Holt-Winters state. Runs plain double smoothing until a full
/// season has been buffered, then switches to additive triple smoothing.
#[derive(Debug)]
struct Smoother {
    alpha: f64,
    beta: f64,
    gamma: f64,
    season: usize,
    level: f64,
    trend: f64,
    /// additive seasonal indices, phase-aligned to observation count
    seasonal: Vec<f64>,
    /// first-season buffer used to initialize the seasonal indices
    init_buf: Vec<f64>,
    /// observations consumed
    n: u64,
}

impl Smoother {
    fn new(alpha: f64, beta: f64, gamma: f64, season: usize) -> Smoother {
        Smoother {
            alpha,
            beta,
            gamma,
            season,
            level: 0.0,
            trend: 0.0,
            seasonal: Vec::new(),
            init_buf: Vec::new(),
            n: 0,
        }
    }

    fn seasonal_ready(&self) -> bool {
        !self.seasonal.is_empty()
    }

    fn observe(&mut self, y: f64) {
        if self.n == 0 {
            self.level = y;
            self.trend = 0.0;
        } else if self.seasonal_ready() {
            let idx = (self.n as usize) % self.season;
            let s = self.seasonal[idx];
            let prev_level = self.level;
            self.level = self.alpha * (y - s) + (1.0 - self.alpha) * (self.level + self.trend);
            self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
            self.seasonal[idx] = self.gamma * (y - self.level) + (1.0 - self.gamma) * s;
        } else {
            let prev_level = self.level;
            self.level = self.alpha * y + (1.0 - self.alpha) * (self.level + self.trend);
            self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
        }
        // a series shorter than one season runs on double smoothing; once
        // the first season completes, its values seed the additive indices
        if self.season > 1 && !self.seasonal_ready() {
            self.init_buf.push(y);
            if self.init_buf.len() == self.season {
                let mean = self.init_buf.iter().sum::<f64>() / self.season as f64;
                self.level = mean;
                self.trend =
                    (self.init_buf[self.season - 1] - self.init_buf[0]) / (self.season - 1) as f64;
                self.seasonal = self.init_buf.iter().map(|&v| v - mean).collect();
                self.init_buf.clear();
            }
        }
        self.n += 1;
    }

    /// Projection `h ≥ 1` steps past the last observation.
    fn forecast(&self, h: usize) -> f64 {
        let h = h.max(1);
        let base = self.level + h as f64 * self.trend;
        if self.seasonal_ready() {
            // phase of the last observation is (n-1) % season
            let idx = (self.n as usize + h - 1) % self.season;
            base + self.seasonal[idx]
        } else {
            base
        }
    }
}

/// A prediction waiting for its target observation to arrive.
#[derive(Debug, Clone, Copy)]
struct Pending {
    /// observation index the prediction refers to
    due: u64,
    naive: f64,
    smooth: f64,
}

/// Trailing (|error|, |actual|) pairs; the ratio of their sums is a
/// weighted MAPE (WMAPE) that stays finite on zero-rate windows.
#[derive(Debug, Default)]
struct ErrWindow {
    pairs: VecDeque<(f64, f64)>,
}

impl ErrWindow {
    fn push(&mut self, err: f64, actual: f64, cap: usize) {
        self.pairs.push_back((err, actual));
        while self.pairs.len() > cap.max(1) {
            self.pairs.pop_front();
        }
    }

    fn wmape(&self) -> Option<f64> {
        if self.pairs.is_empty() {
            return None;
        }
        let (err, act) = self
            .pairs
            .iter()
            .fold((0.0, 0.0), |(e, a), &(pe, pa)| (e + pe, a + pa));
        if err <= 1e-12 {
            return Some(0.0);
        }
        Some(err / act.max(1e-9))
    }
}

/// Online short-horizon forecaster with per-horizon error tracking and
/// automatic model selection.
#[derive(Debug)]
pub struct Forecaster {
    cfg: ForecastConfig,
    smoother: Smoother,
    /// last `max(season, 1)` observations for the seasonal-naive baseline
    history: VecDeque<f64>,
    pending: VecDeque<Pending>,
    errs_naive: ErrWindow,
    errs_smooth: ErrWindow,
    /// finite observations consumed
    step: u64,
}

impl Forecaster {
    pub fn new(cfg: ForecastConfig) -> Forecaster {
        let cfg = ForecastConfig {
            horizon: cfg.horizon.max(1),
            season: if cfg.season == 1 { 0 } else { cfg.season },
            alpha: cfg.alpha.clamp(0.01, 1.0),
            beta: cfg.beta.clamp(0.01, 1.0),
            gamma: cfg.gamma.clamp(0.01, 1.0),
            err_window: cfg.err_window.max(8),
            min_history: cfg.min_history.max(2),
        };
        Forecaster {
            smoother: Smoother::new(cfg.alpha, cfg.beta, cfg.gamma, cfg.season),
            history: VecDeque::with_capacity(cfg.season.max(1)),
            pending: VecDeque::new(),
            errs_naive: ErrWindow::default(),
            errs_smooth: ErrWindow::default(),
            step: 0,
            cfg,
        }
    }

    /// Finite observations consumed so far.
    pub fn len(&self) -> usize {
        self.step as usize
    }

    pub fn is_empty(&self) -> bool {
        self.step == 0
    }

    /// Feed a backlog (e.g. the stored Table II window) in one call.
    pub fn warm_start(&mut self, values: &[f64]) {
        for &v in values {
            self.observe(v);
        }
    }

    /// Consume one sample. Non-finite values are ignored entirely, so the
    /// forecaster can never be poisoned into NaN state.
    pub fn observe(&mut self, y: f64) {
        if !y.is_finite() {
            return;
        }
        // mature every prediction whose target step this observation is
        while let Some(p) = self.pending.front().copied() {
            if p.due > self.step {
                break;
            }
            self.pending.pop_front();
            if p.due == self.step {
                let cap = self.cfg.err_window;
                self.errs_naive.push((p.naive - y).abs(), y.abs(), cap);
                self.errs_smooth.push((p.smooth - y).abs(), y.abs(), cap);
            }
        }

        self.smoother.observe(y);
        self.history.push_back(y);
        while self.history.len() > self.cfg.season.max(1) {
            self.history.pop_front();
        }
        self.step += 1;

        // book the predictions this sample enables, to be scored when the
        // horizon-ahead observation lands
        if self.len() >= self.cfg.min_history {
            let h = self.cfg.horizon;
            if let Some(naive) = self.naive_forecast(h) {
                self.pending.push_back(Pending {
                    due: self.step - 1 + h as u64,
                    naive,
                    smooth: self.smoother.forecast(h).max(0.0),
                });
            }
        }
    }

    /// Seasonal-naive projection: the value one season before the target
    /// step; the last observation when no (full) season is available.
    fn naive_forecast(&self, h: usize) -> Option<f64> {
        let last = *self.history.back()?;
        let m = self.cfg.season;
        if m >= 2 && self.history.len() >= m {
            // target step t+h looks back to t+h-m; for h <= m that index
            // is len-m+(h-1); larger horizons wrap within the season
            let off = (h.max(1) - 1) % m;
            Some(self.history[self.history.len() - m + off])
        } else {
            Some(last)
        }
    }

    /// Trailing WMAPE of each model at the configured horizon.
    fn errors(&self) -> (Option<f64>, Option<f64>) {
        (self.errs_naive.wmape(), self.errs_smooth.wmape())
    }

    /// The model [`Forecaster::forecast`] currently answers with: whichever
    /// has the lower matured trailing error, smoothing by default.
    pub fn method(&self) -> Method {
        match self.errors() {
            (Some(n), Some(s)) if n < s => Method::SeasonalNaive,
            _ => Method::Smoothing,
        }
    }

    /// Trailing WMAPE of the selected model. `None` until a prediction has
    /// matured.
    pub fn error(&self) -> Option<f64> {
        let (n, s) = self.errors();
        match self.method() {
            Method::SeasonalNaive => n,
            Method::Smoothing => s.or(n),
        }
    }

    /// True once the trailing error exceeds `budget` — the signal to stand
    /// proactive planning down and fall back to the reactive loop.
    pub fn degraded(&self, budget: f64) -> bool {
        self.error().map(|e| e > budget).unwrap_or(false)
    }

    /// Predicted value `h ≥ 1` steps ahead, clamped non-negative (rates
    /// cannot go below zero). `None` until `min_history` observations.
    pub fn forecast(&self, h: usize) -> Option<f64> {
        if self.len() < self.cfg.min_history {
            return None;
        }
        let v = match self.method() {
            Method::SeasonalNaive => self.naive_forecast(h)?,
            Method::Smoothing => self.smoother.forecast(h),
        };
        v.is_finite().then_some(v.max(0.0))
    }

    /// [`Forecaster::forecast`] at the configured horizon.
    pub fn forecast_horizon(&self) -> Option<f64> {
        self.forecast(self.cfg.horizon)
    }
}

/// A family of [`Forecaster`]s keyed by workload component (one per
/// tenant), forecasting a mixture as the sum of its parts.
///
/// ENOVA's mixture scenario co-locates tenants with very different arrival
/// shapes; one aggregate forecaster smears them together, while per-tenant
/// models can see e.g. the batch tenant's trough even while the chat
/// tenant holds steady — the signal the cost-aware scale-down needs.
///
/// Contract: the caller (the supervisor's sampling loop) must observe
/// **every** key on **every** tick — zero-rate ticks included — so all
/// component models mature in lockstep and [`MultiForecaster::forecast_sum`]
/// never silently under-counts demand by summing a partial mixture.
#[derive(Debug)]
pub struct MultiForecaster {
    cfg: ForecastConfig,
    by_key: BTreeMap<String, Forecaster>,
}

impl MultiForecaster {
    pub fn new(cfg: ForecastConfig) -> MultiForecaster {
        MultiForecaster {
            cfg,
            by_key: BTreeMap::new(),
        }
    }

    /// Number of tracked components.
    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Feed one sample for one component, creating its model on first use.
    pub fn observe(&mut self, key: &str, y: f64) {
        if let Some(f) = self.by_key.get_mut(key) {
            f.observe(y);
        } else {
            let mut f = Forecaster::new(self.cfg.clone());
            f.observe(y);
            self.by_key.insert(key.to_string(), f);
        }
    }

    pub fn get(&self, key: &str) -> Option<&Forecaster> {
        self.by_key.get(key)
    }

    /// Tracked keys in stable (sorted) order.
    pub fn keys(&self) -> Vec<&str> {
        self.by_key.keys().map(String::as_str).collect()
    }

    /// Sum of the per-component forecasts `h` steps ahead. `None` until
    /// every component answers: a partial sum would under-estimate the
    /// mixture and is worse than no answer for both scale-up and the
    /// trough scale-down.
    pub fn forecast_sum(&self, h: usize) -> Option<f64> {
        if self.by_key.is_empty() {
            return None;
        }
        let mut total = 0.0;
        for f in self.by_key.values() {
            total += f.forecast(h)?;
        }
        Some(total)
    }

    /// Worst trailing WMAPE across components. `None` until any matures.
    pub fn error(&self) -> Option<f64> {
        self.by_key
            .values()
            .filter_map(Forecaster::error)
            .fold(None, |acc, e| Some(acc.map_or(e, |a: f64| a.max(e))))
    }

    /// The mixture forecast is only as good as its worst component.
    pub fn degraded(&self, budget: f64) -> bool {
        self.by_key.values().any(|f| f.degraded(budget))
    }
}

/// Replicas needed to serve `pred_rps` with `capacity_rps` per replica and
/// a relative safety `headroom`, clamped to `[min, max]` — the pure core
/// of the supervisor's proactive planner.
pub fn replicas_for_rate(
    pred_rps: f64,
    capacity_rps: f64,
    headroom: f64,
    min: usize,
    max: usize,
) -> usize {
    let min = min.max(1);
    let max = max.max(min);
    if !pred_rps.is_finite() || capacity_rps <= 0.0 {
        return min;
    }
    let demand = pred_rps.max(0.0) * (1.0 + headroom.max(0.0));
    let needed = (demand / capacity_rps).ceil();
    if !needed.is_finite() {
        return max;
    }
    (needed as usize).clamp(min, max)
}

/// Cluster form of [`replicas_for_rate`]: replica slots can have
/// heterogeneous service capacities (different GPUs on different nodes),
/// so the planner fills the fastest slots first and returns how many
/// replicas are needed for their summed capacity to cover the predicted
/// demand (with relative `headroom`). The answer is floored at `min` and
/// capped at `slots.len()`; when the demand exceeds everything the
/// cluster can offer, every slot is asked for — capacity the cluster does
/// not have cannot be planned into existence.
pub fn replicas_for_cluster_rate(
    pred_rps: f64,
    slot_capacities_rps: &[f64],
    headroom: f64,
    min: usize,
) -> usize {
    let min = min.max(1);
    if slot_capacities_rps.is_empty() {
        return min;
    }
    let max = slot_capacities_rps.len();
    if !pred_rps.is_finite() {
        return min.min(max);
    }
    let demand = pred_rps.max(0.0) * (1.0 + headroom.max(0.0));
    if demand <= 0.0 {
        return min.min(max);
    }
    let mut caps: Vec<f64> = slot_capacities_rps
        .iter()
        .map(|c| if c.is_finite() { c.max(0.0) } else { 0.0 })
        .collect();
    caps.sort_by(|a, b| b.total_cmp(a));
    let mut covered = 0.0;
    for (i, cap) in caps.iter().enumerate() {
        covered += cap;
        if covered >= demand {
            return (i + 1).clamp(min.min(max), max);
        }
    }
    // demand exceeds total cluster capacity: all hands
    max
}

#[cfg(test)]
mod tests {
    use super::*;

    fn forecaster(season: usize) -> Forecaster {
        Forecaster::new(ForecastConfig {
            horizon: 3,
            season,
            min_history: 4,
            ..ForecastConfig::default()
        })
    }

    #[test]
    fn empty_window_answers_none() {
        let f = forecaster(0);
        assert!(f.is_empty());
        assert_eq!(f.forecast(3), None);
        assert_eq!(f.error(), None);
        assert!(!f.degraded(0.1), "no evidence is not degradation");
    }

    #[test]
    fn single_sample_window_answers_none() {
        // mirrors the config module's degenerate-window refusals: one
        // point is not evidence to extrapolate from
        let mut f = forecaster(0);
        f.observe(7.0);
        assert_eq!(f.len(), 1);
        assert_eq!(f.forecast(3), None);
        assert_eq!(f.error(), None);
    }

    #[test]
    fn constant_series_forecasts_the_constant() {
        let mut f = forecaster(0);
        for _ in 0..50 {
            f.observe(4.25);
        }
        let pred = f.forecast(3).expect("enough history");
        assert!((pred - 4.25).abs() < 1e-9, "got {pred}");
        // matured predictions were perfect
        assert_eq!(f.error(), Some(0.0));
        assert!(!f.degraded(0.01));
    }

    #[test]
    fn zero_variance_zero_valued_window_is_nan_free() {
        // an all-idle window: rates are 0.0 everywhere. WMAPE must not
        // divide by zero and every output must be finite.
        let mut f = forecaster(6);
        for _ in 0..40 {
            f.observe(0.0);
        }
        let pred = f.forecast(3).expect("enough history");
        assert!(pred.is_finite());
        assert!(pred.abs() < 1e-9, "idle stays idle: {pred}");
        let err = f.error().expect("predictions matured");
        assert!(err.is_finite());
        assert_eq!(err, 0.0);
    }

    #[test]
    fn series_shorter_than_one_season_falls_back() {
        // season of 24 samples but only 10 observed: the seasonal models
        // cannot engage, yet forecasts still come (double smoothing /
        // last-value) and are finite
        let mut f = forecaster(24);
        for i in 0..10 {
            f.observe(5.0 + (i % 2) as f64);
        }
        let pred = f.forecast(3).expect("falls back below one season");
        assert!(pred.is_finite());
        assert!((3.0..=9.0).contains(&pred), "sane fallback: {pred}");
    }

    #[test]
    fn non_finite_observations_are_ignored() {
        let mut f = forecaster(0);
        for _ in 0..10 {
            f.observe(3.0);
        }
        f.observe(f64::NAN);
        f.observe(f64::INFINITY);
        assert_eq!(f.len(), 10, "poison samples not consumed");
        let pred = f.forecast(3).unwrap();
        assert!(pred.is_finite());
        assert!((pred - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ramp_is_projected_by_the_trend() {
        let mut f = Forecaster::new(ForecastConfig {
            horizon: 5,
            season: 0,
            min_history: 4,
            ..ForecastConfig::default()
        });
        for i in 0..80 {
            f.observe(i as f64);
        }
        // last observation 79; a trend-aware model lands near 84 at h=5,
        // far above the last value a naive model would answer
        let pred = f.forecast(5).unwrap();
        assert!(pred > 80.0, "trend extrapolated: {pred}");
        assert!(pred < 90.0, "not runaway: {pred}");
    }

    #[test]
    fn seasonal_series_is_tracked_across_seasons() {
        let season = 12;
        let mut f = Forecaster::new(ForecastConfig {
            horizon: 3,
            season,
            min_history: 4,
            ..ForecastConfig::default()
        });
        // a strongly seasonal sawtooth, several seasons long
        let wave = |i: usize| 10.0 + 8.0 * ((i % season) as f64 - 6.0).abs();
        for i in 0..(season * 12) {
            f.observe(wave(i));
        }
        let err = f.error().expect("errors matured");
        assert!(err.is_finite());
        assert!(err < 0.5, "seasonal structure is learnable: {err}");
        // the forecast tracks the wave, not its mean
        let t = season * 12;
        let pred = f.forecast(3).unwrap();
        let actual = wave(t + 2); // h=3 ahead of last index t-1
        assert!(
            (pred - actual).abs() < 8.0,
            "pred {pred} vs upcoming {actual}"
        );
    }

    #[test]
    fn degraded_flags_a_broken_forecast() {
        let mut f = Forecaster::new(ForecastConfig {
            horizon: 2,
            season: 0,
            min_history: 2,
            err_window: 16,
            ..ForecastConfig::default()
        });
        // calm series, then a violent regime change the smoother lags on:
        // matured predictions become badly wrong
        for _ in 0..20 {
            f.observe(1.0);
        }
        for i in 0..10 {
            f.observe(1.0 + i as f64 * 50.0);
        }
        let err = f.error().unwrap();
        assert!(err.is_finite());
        assert!(f.degraded(0.2), "regime change must trip the budget: {err}");
    }

    #[test]
    fn warm_start_equals_sequential_observe() {
        let values: Vec<f64> = (0..30).map(|i| (i as f64 * 0.7).sin() + 2.0).collect();
        let mut a = forecaster(0);
        a.warm_start(&values);
        let mut b = forecaster(0);
        for &v in &values {
            b.observe(v);
        }
        assert_eq!(a.forecast(3), b.forecast(3));
        assert_eq!(a.error(), b.error());
    }

    #[test]
    fn replicas_for_rate_sizing() {
        // 55 rps at 25 rps/replica with 10% headroom -> ceil(60.5/25) = 3
        assert_eq!(replicas_for_rate(55.0, 25.0, 0.1, 1, 8), 3);
        // clamped by max
        assert_eq!(replicas_for_rate(1000.0, 10.0, 0.0, 1, 4), 4);
        // clamped by min, and min is at least 1
        assert_eq!(replicas_for_rate(0.0, 10.0, 0.0, 2, 4), 2);
        assert_eq!(replicas_for_rate(0.0, 10.0, 0.0, 0, 4), 1);
        // degenerate capacity / non-finite predictions never panic
        assert_eq!(replicas_for_rate(5.0, 0.0, 0.0, 1, 4), 1);
        assert_eq!(replicas_for_rate(f64::NAN, 10.0, 0.0, 1, 4), 1);
        assert_eq!(replicas_for_rate(f64::INFINITY, 10.0, 0.0, 1, 4), 4);
    }

    #[test]
    fn cluster_rate_fills_fastest_slots_first() {
        // one fast slot covers 30 rps alone; the uniform case matches the
        // homogeneous planner
        assert_eq!(replicas_for_cluster_rate(30.0, &[10.0, 40.0, 10.0], 0.0, 1), 1);
        assert_eq!(replicas_for_cluster_rate(55.0, &[25.0, 25.0, 25.0, 25.0], 0.1, 1), 3);
        // heterogeneous: 60 rps needs the 40-rps slot plus one 15-rps slot
        assert_eq!(replicas_for_cluster_rate(50.0, &[15.0, 40.0, 15.0], 0.0, 1), 2);
    }

    #[test]
    fn cluster_rate_degenerate_inputs_never_panic() {
        // no slots at all: the floor is still answered
        assert_eq!(replicas_for_cluster_rate(10.0, &[], 0.0, 2), 2);
        // demand over total capacity asks for every slot — the planner
        // cannot invent capacity the cluster does not have
        assert_eq!(replicas_for_cluster_rate(1000.0, &[10.0, 10.0], 0.0, 1), 2);
        assert_eq!(replicas_for_cluster_rate(5.0, &[0.0, 0.0], 0.0, 1), 2);
        // zero / non-finite predictions fall back to the floor, capped by
        // the slot count
        assert_eq!(replicas_for_cluster_rate(0.0, &[10.0, 10.0, 10.0], 0.0, 2), 2);
        assert_eq!(replicas_for_cluster_rate(f64::NAN, &[10.0; 4], 0.0, 1), 1);
        assert_eq!(replicas_for_cluster_rate(10.0, &[f64::NAN, 20.0], 0.0, 1), 1);
        // min floor larger than the cluster clamps to the slot count
        assert_eq!(replicas_for_cluster_rate(1.0, &[10.0], 0.0, 5), 1);
    }

    #[test]
    fn multi_forecaster_sums_components() {
        let mut m = MultiForecaster::new(ForecastConfig {
            horizon: 3,
            season: 0,
            min_history: 4,
            ..ForecastConfig::default()
        });
        assert!(m.is_empty());
        assert_eq!(m.forecast_sum(3), None);
        // two constant tenants: the mixture forecast is their sum
        for _ in 0..20 {
            m.observe("chat", 4.0);
            m.observe("codegen", 1.5);
        }
        assert_eq!(m.len(), 2);
        assert_eq!(m.keys(), vec!["chat", "codegen"]);
        let sum = m.forecast_sum(3).expect("both matured");
        assert!((sum - 5.5).abs() < 1e-6, "got {sum}");
        // component models stay isolated
        let chat = m.get("chat").unwrap().forecast(3).unwrap();
        assert!((chat - 4.0).abs() < 1e-6);
        assert!(!m.degraded(0.1));
        assert_eq!(m.error(), Some(0.0));
    }

    #[test]
    fn multi_forecaster_withholds_partial_sums() {
        let mut m = MultiForecaster::new(ForecastConfig {
            horizon: 3,
            season: 0,
            min_history: 4,
            ..ForecastConfig::default()
        });
        for _ in 0..20 {
            m.observe("chat", 2.0);
        }
        // a brand-new component without history blocks the sum rather than
        // letting the mixture silently under-count
        m.observe("late", 9.0);
        assert_eq!(m.forecast_sum(3), None);
        for _ in 0..10 {
            m.observe("chat", 2.0);
            m.observe("late", 9.0);
        }
        let sum = m.forecast_sum(3).expect("late component matured");
        assert!((sum - 11.0).abs() < 0.5, "got {sum}");
    }

    #[test]
    fn multi_forecaster_degrades_on_worst_component() {
        let cfg = ForecastConfig {
            horizon: 2,
            season: 0,
            min_history: 2,
            err_window: 16,
            ..ForecastConfig::default()
        };
        let mut m = MultiForecaster::new(cfg);
        for _ in 0..20 {
            m.observe("calm", 1.0);
            m.observe("wild", 1.0);
        }
        for i in 0..10 {
            m.observe("calm", 1.0);
            m.observe("wild", 1.0 + i as f64 * 50.0);
        }
        assert!(m.degraded(0.2), "one broken component degrades the mixture");
        assert!(m.error().unwrap() > 0.2);
    }

    #[test]
    fn model_selection_tracks_the_better_model() {
        // white-noise-free constant: both models are perfect, smoothing is
        // the default tie-break
        let mut f = forecaster(0);
        for _ in 0..30 {
            f.observe(2.0);
        }
        assert_eq!(f.method(), Method::Smoothing);
        assert_eq!(f.method().name(), "smoothing");
        assert_eq!(Method::SeasonalNaive.name(), "seasonal_naive");
    }
}
