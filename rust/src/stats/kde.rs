//! Gaussian kernel density estimation (Parzen) with Silverman bandwidth.
//!
//! The paper uses KDE twice: to pick `n_limit` / `t^r_limit` from windows of
//! monitoring metrics (§IV-A-1) and to pick per-community `max_tokens` from
//! output-length distributions (§IV-A-3). Both reduce to "estimate the
//! density, take a high quantile of it", so the main entry point here is
//! [`Kde::quantile`], a numeric inversion of the KDE's CDF.

use super::descriptive;
use super::tdist::norm_cdf;

#[derive(Debug, Clone)]
pub struct Kde {
    samples: Vec<f64>,
    pub bandwidth: f64,
}

impl Kde {
    /// Fit with Silverman's rule-of-thumb bandwidth:
    /// h = 0.9 · min(σ̂, IQR/1.34) · n^(−1/5).
    pub fn fit(samples: &[f64]) -> Option<Kde> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let sigma = descriptive::std_dev(&sorted);
        let iqr = descriptive::quantile_sorted(&sorted, 0.75)
            - descriptive::quantile_sorted(&sorted, 0.25);
        let spread = if iqr > 0.0 {
            sigma.min(iqr / 1.34)
        } else {
            sigma
        };
        let n = sorted.len() as f64;
        let bandwidth = if spread > 1e-12 {
            0.9 * spread * n.powf(-0.2)
        } else {
            // degenerate (all-equal) sample: a nominal width so the CDF is
            // still invertible
            (sorted[0].abs() * 1e-3).max(1e-6)
        };
        Some(Kde {
            samples: sorted,
            bandwidth,
        })
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Density estimate at x.
    pub fn pdf(&self, x: f64) -> f64 {
        let h = self.bandwidth;
        let norm = 1.0 / ((2.0 * std::f64::consts::PI).sqrt() * h * self.samples.len() as f64);
        self.samples
            .iter()
            .map(|s| {
                let z = (x - s) / h;
                (-0.5 * z * z).exp()
            })
            .sum::<f64>()
            * norm
    }

    /// CDF of the KDE (sum of kernel CDFs).
    pub fn cdf(&self, x: f64) -> f64 {
        let h = self.bandwidth;
        self.samples
            .iter()
            .map(|s| norm_cdf((x - s) / h))
            .sum::<f64>()
            / self.samples.len() as f64
    }

    /// Quantile via bisection on the CDF. `q` in (0,1).
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(1e-9, 1.0 - 1e-9);
        let (mut lo, mut hi) = (
            self.samples[0] - 10.0 * self.bandwidth,
            self.samples[self.samples.len() - 1] + 10.0 * self.bandwidth,
        );
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.cdf(mid) < q {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Density mode via grid scan + local refinement (used to report the
    /// "typical" execution time).
    pub fn mode(&self) -> f64 {
        let lo = self.samples[0] - 3.0 * self.bandwidth;
        let hi = self.samples[self.samples.len() - 1] + 3.0 * self.bandwidth;
        let mut best = (lo, self.pdf(lo));
        let steps = 256;
        for i in 0..=steps {
            let x = lo + (hi - lo) * i as f64 / steps as f64;
            let d = self.pdf(x);
            if d > best.1 {
                best = (x, d);
            }
        }
        best.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn gaussian_sample_quantiles() {
        let mut rng = Pcg64::new(11);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal_with(5.0, 2.0)).collect();
        let kde = Kde::fit(&xs).unwrap();
        assert!((kde.quantile(0.5) - 5.0).abs() < 0.15);
        assert!((kde.quantile(0.975) - (5.0 + 1.96 * 2.0)).abs() < 0.4);
        assert!((kde.mode() - 5.0).abs() < 0.4);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let mut rng = Pcg64::new(12);
        let xs: Vec<f64> = (0..300).map(|_| rng.lognormal(0.0, 1.0)).collect();
        let kde = Kde::fit(&xs).unwrap();
        let mut prev = 0.0;
        for i in 0..50 {
            let x = -2.0 + i as f64 * 0.5;
            let c = kde.cdf(x);
            assert!((0.0..=1.0).contains(&c));
            assert!(c >= prev - 1e-12);
            prev = c;
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        crate::util::prop::check("kde quantile∘cdf ≈ id", 30, |g| {
            let n = g.usize_in(10, 200);
            let xs: Vec<f64> = (0..n).map(|_| g.f64_in(-10.0, 10.0)).collect();
            let kde = match Kde::fit(&xs) {
                Some(k) => k,
                None => return Ok(()),
            };
            for &q in &[0.1, 0.5, 0.9, 0.99] {
                let x = kde.quantile(q);
                crate::util::prop::ensure_close(kde.cdf(x), q, 1e-3, "cdf(quantile(q))")?;
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_all_equal() {
        let kde = Kde::fit(&[3.0; 50]).unwrap();
        assert!((kde.quantile(0.99) - 3.0).abs() < 0.1);
    }

    #[test]
    fn empty_rejected() {
        assert!(Kde::fit(&[]).is_none());
    }
}
