//! Descriptive statistics over `&[f64]` windows.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated quantile, q in [0,1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// Quantile over an already-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Pearson correlation coefficient.
pub fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [0.0, 10.0];
        assert_eq!(quantile(&xs, 0.25), 2.5);
        assert_eq!(quantile(&xs, 1.0), 10.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn correlation_signs() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((correlation(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((correlation(&xs, &zs) + 1.0).abs() < 1e-12);
        let flat = vec![3.0; 50];
        assert_eq!(correlation(&xs, &flat), 0.0);
    }
}
