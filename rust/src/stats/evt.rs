//! Extreme-value statistics: Gumbel fit, generalized Pareto fit, and the
//! peaks-over-threshold (POT) auto-threshold of Siffer et al. (KDD'17) that
//! the paper uses to set the anomaly-detection threshold (§IV-B) and to
//! estimate `n_limit` from saturated metric windows (§IV-A-1).

use super::descriptive;

/// Gumbel (type-I extreme value) distribution fitted by moments:
/// scale β = s·√6/π, location μ = x̄ − γ·β.
#[derive(Debug, Clone, Copy)]
pub struct Gumbel {
    pub location: f64,
    pub scale: f64,
}

pub const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;

impl Gumbel {
    pub fn fit(xs: &[f64]) -> Option<Gumbel> {
        if xs.len() < 3 {
            return None;
        }
        let s = descriptive::std_dev(xs);
        if s < 1e-12 {
            return Some(Gumbel {
                location: descriptive::mean(xs),
                scale: 1e-9,
            });
        }
        let scale = s * 6f64.sqrt() / std::f64::consts::PI;
        let location = descriptive::mean(xs) - EULER_GAMMA * scale;
        Some(Gumbel { location, scale })
    }

    pub fn cdf(&self, x: f64) -> f64 {
        (-(-(x - self.location) / self.scale).exp()).exp()
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(1e-12, 1.0 - 1e-12);
        self.location - self.scale * (-(q.ln())).ln()
    }
}

/// Generalized Pareto distribution over threshold excesses, fitted by the
/// method of moments (Hosking & Wallis): ξ = ½(1 − x̄²/s²),
/// σ = ½·x̄·(x̄²/s² + 1).
#[derive(Debug, Clone, Copy)]
pub struct Gpd {
    pub shape: f64, // ξ
    pub scale: f64, // σ
}

impl Gpd {
    pub fn fit(excesses: &[f64]) -> Option<Gpd> {
        if excesses.len() < 5 {
            return None;
        }
        let m = descriptive::mean(excesses);
        let v = descriptive::variance(excesses);
        if m <= 0.0 || v <= 1e-12 {
            return None;
        }
        let r = m * m / v;
        let shape = 0.5 * (1.0 - r);
        let scale = 0.5 * m * (r + 1.0);
        Some(Gpd { shape, scale })
    }

    /// Survival function P(X > x) for x ≥ 0.
    pub fn sf(&self, x: f64) -> f64 {
        if self.shape.abs() < 1e-9 {
            (-x / self.scale).exp()
        } else {
            let base = 1.0 + self.shape * x / self.scale;
            if base <= 0.0 {
                0.0
            } else {
                base.powf(-1.0 / self.shape)
            }
        }
    }

    /// Quantile of the excess distribution at survival probability `p`.
    pub fn quantile_sf(&self, p: f64) -> f64 {
        let p = p.clamp(1e-12, 1.0);
        if self.shape.abs() < 1e-9 {
            -self.scale * p.ln()
        } else {
            self.scale / self.shape * (p.powf(-self.shape) - 1.0)
        }
    }
}

/// Peaks-over-threshold auto-thresholding (SPOT, Siffer et al. 2017).
///
/// Given a calibration sample and a target risk `q` (probability that a
/// *normal* point exceeds the final threshold), fits a GPD to the excesses
/// over an initial high quantile `t0` and extrapolates:
///
///   z_q = t0 + (σ̂/ξ̂)·[ (q·n/N_t)^(−ξ̂) − 1 ]
#[derive(Debug, Clone, Copy)]
pub struct PotThreshold {
    pub initial: f64,
    pub threshold: f64,
    pub gpd: Option<Gpd>,
    pub n_excesses: usize,
}

pub fn pot_threshold(calibration: &[f64], q: f64, init_quantile: f64) -> Option<PotThreshold> {
    if calibration.len() < 20 {
        return None;
    }
    let t0 = descriptive::quantile(calibration, init_quantile);
    let excesses: Vec<f64> = calibration
        .iter()
        .filter(|&&x| x > t0)
        .map(|&x| x - t0)
        .collect();
    let n = calibration.len() as f64;
    let nt = excesses.len() as f64;
    let gpd = Gpd::fit(&excesses);
    let threshold = match gpd {
        Some(g) => {
            // survival within the excess distribution that corresponds to
            // overall exceedance probability q
            let p = (q * n / nt).min(1.0);
            t0 + g.quantile_sf(p)
        }
        // too few excesses to fit: fall back to the empirical extreme
        None => descriptive::max(calibration) * 1.05,
    };
    Some(PotThreshold {
        initial: t0,
        threshold,
        gpd,
        n_excesses: excesses.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn gumbel_fit_recovers_parameters() {
        let mut rng = Pcg64::new(21);
        let xs: Vec<f64> = (0..20_000).map(|_| 3.0 + 2.0 * rng.gumbel()).collect();
        let g = Gumbel::fit(&xs).unwrap();
        assert!((g.location - 3.0).abs() < 0.1, "loc {}", g.location);
        assert!((g.scale - 2.0).abs() < 0.1, "scale {}", g.scale);
        // quantile inverts cdf
        let x = g.quantile(0.95);
        assert!((g.cdf(x) - 0.95).abs() < 1e-9);
    }

    #[test]
    fn gpd_fit_exponential_case() {
        // exponential = GPD with ξ=0, σ=1/rate
        let mut rng = Pcg64::new(22);
        let xs: Vec<f64> = (0..30_000).map(|_| rng.exponential(0.5)).collect();
        let g = Gpd::fit(&xs).unwrap();
        assert!(g.shape.abs() < 0.05, "shape {}", g.shape);
        assert!((g.scale - 2.0).abs() < 0.1, "scale {}", g.scale);
    }

    #[test]
    fn gpd_quantile_inverts_sf() {
        let g = Gpd {
            shape: 0.2,
            scale: 1.5,
        };
        for &p in &[0.5, 0.1, 0.01, 1e-4] {
            let x = g.quantile_sf(p);
            assert!((g.sf(x) - p).abs() / p < 1e-6, "p={p}");
        }
    }

    #[test]
    fn pot_threshold_controls_false_positives() {
        let mut rng = Pcg64::new(23);
        let cal: Vec<f64> = (0..20_000).map(|_| rng.normal().abs()).collect();
        let pot = pot_threshold(&cal, 1e-4, 0.98).unwrap();
        assert!(pot.threshold > pot.initial);
        // fresh normal data should virtually never exceed the threshold
        let exceed = (0..100_000)
            .filter(|_| rng.normal().abs() > pot.threshold)
            .count();
        assert!(exceed < 60, "exceed={exceed} thr={}", pot.threshold);
        // ...but genuinely extreme points should
        assert!(8.0 > pot.threshold);
    }

    #[test]
    fn pot_needs_enough_data() {
        assert!(pot_threshold(&[1.0; 10], 1e-3, 0.98).is_none());
    }
}
