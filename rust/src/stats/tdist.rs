//! Student-t distribution CDF via the regularized incomplete beta function
//! (Lentz continued fraction). Needed for the OLS slope t-test of §IV-A-1.

/// ln Γ(x) — Lanczos approximation (g=7, n=9), |err| < 1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta I_x(a, b), continued-fraction evaluation.
pub fn betai(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front =
        ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * betacf(a, b, x) / a
    } else {
        1.0 - front * betacf(b, a, 1.0 - x) / b
    }
}

fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-14;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// CDF of Student's t with `df` degrees of freedom.
pub fn t_cdf(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    let p = 0.5 * betai(0.5 * df, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Two-sided p-value for a t statistic.
pub fn t_test_p_value(t: f64, df: f64) -> f64 {
    if df <= 0.0 {
        return 1.0;
    }
    (2.0 * (1.0 - t_cdf(t.abs(), df))).clamp(0.0, 1.0)
}

/// Standard normal CDF (used by KDE quantiles and POT diagnostics).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function, Abramowitz–Stegun 7.1.26 refined (|err| < 1.2e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t
            - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..10u64 {
            let fact: f64 = (1..n).map(|k| k as f64).product();
            assert!((ln_gamma(n as f64) - fact.ln()).abs() < 1e-10, "n={n}");
        }
        // Γ(0.5) = sqrt(π)
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn t_cdf_reference_values() {
        // reference values from scipy.stats.t.cdf
        assert!((t_cdf(0.0, 5.0) - 0.5).abs() < 1e-12);
        assert!((t_cdf(2.0, 10.0) - 0.963_306).abs() < 1e-4);
        assert!((t_cdf(-1.0, 3.0) - 0.195_501).abs() < 1e-4);
        assert!((t_cdf(2.228, 10.0) - 0.975).abs() < 1e-3); // t_{0.975,10}
    }

    #[test]
    fn p_value_symmetry() {
        let p1 = t_test_p_value(2.5, 20.0);
        let p2 = t_test_p_value(-2.5, 20.0);
        assert!((p1 - p2).abs() < 1e-12);
        assert!(p1 < 0.05);
        assert!(t_test_p_value(0.1, 20.0) > 0.5);
    }

    #[test]
    fn norm_cdf_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_cdf(-1.96) - 0.025).abs() < 1e-3);
    }
}
