//! Principal component analysis via power iteration with deflation.
//! Used by the Fig. 8 reproduction (2-D projection of request embeddings).

/// Result of a top-k PCA of row-major data `[n, d]`.
#[derive(Debug, Clone)]
pub struct Pca {
    pub mean: Vec<f64>,
    /// `k` principal axes, each of length `d`, unit norm.
    pub components: Vec<Vec<f64>>,
    /// eigenvalues (variance along each component)
    pub explained: Vec<f64>,
}

impl Pca {
    /// Fit top-`k` components. `data` is `n` rows of dimension `d`.
    pub fn fit(data: &[Vec<f64>], k: usize) -> Option<Pca> {
        let n = data.len();
        if n < 2 {
            return None;
        }
        let d = data[0].len();
        let mut mean = vec![0.0; d];
        for row in data {
            for (m, x) in mean.iter_mut().zip(row) {
                *m += x;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        // covariance (d×d, dense; embeddings are d=64 so this is cheap)
        let mut cov = vec![vec![0.0; d]; d];
        for row in data {
            for i in 0..d {
                let ci = row[i] - mean[i];
                for j in i..d {
                    cov[i][j] += ci * (row[j] - mean[j]);
                }
            }
        }
        for i in 0..d {
            for j in i..d {
                cov[i][j] /= (n - 1) as f64;
                cov[j][i] = cov[i][j];
            }
        }

        let mut components = Vec::new();
        let mut explained = Vec::new();
        let mut work = cov;
        for comp_idx in 0..k.min(d) {
            let (v, lambda) = power_iterate(&work, 500, 1e-10, comp_idx as u64)?;
            if lambda <= 1e-12 {
                break;
            }
            // deflate: work -= λ v vᵀ
            for i in 0..d {
                for j in 0..d {
                    work[i][j] -= lambda * v[i] * v[j];
                }
            }
            components.push(v);
            explained.push(lambda);
        }
        Some(Pca {
            mean,
            components,
            explained,
        })
    }

    /// Project a row onto the fitted components.
    pub fn transform(&self, row: &[f64]) -> Vec<f64> {
        self.components
            .iter()
            .map(|c| {
                c.iter()
                    .zip(row.iter().zip(&self.mean))
                    .map(|(ci, (x, m))| ci * (x - m))
                    .sum()
            })
            .collect()
    }
}

fn power_iterate(
    mat: &[Vec<f64>],
    iters: usize,
    tol: f64,
    seed: u64,
) -> Option<(Vec<f64>, f64)> {
    let d = mat.len();
    let mut rng = crate::util::rng::Pcg64::new(pca_seed(seed));
    let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    normalize(&mut v)?;
    let mut lambda = 0.0;
    for _ in 0..iters {
        let mut w = vec![0.0; d];
        for i in 0..d {
            let mut s = 0.0;
            for j in 0..d {
                s += mat[i][j] * v[j];
            }
            w[i] = s;
        }
        let new_lambda: f64 = w.iter().zip(&v).map(|(a, b)| a * b).sum();
        if normalize(&mut w).is_none() {
            return Some((v, 0.0));
        }
        let delta = (new_lambda - lambda).abs();
        v = w;
        lambda = new_lambda;
        if delta < tol * (1.0 + lambda.abs()) {
            break;
        }
    }
    Some((v, lambda.max(0.0)))
}

fn pca_seed(seed: u64) -> u64 {
    0x9e37_79b9 ^ (seed.wrapping_mul(0x100_0193) + 17)
}

fn normalize(v: &mut [f64]) -> Option<()> {
    let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm < 1e-300 {
        return None;
    }
    for x in v.iter_mut() {
        *x /= norm;
    }
    Some(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn synth_anisotropic(n: usize) -> Vec<Vec<f64>> {
        // variance 9 along (1,1,0)/√2, variance 1 along (1,-1,0)/√2, 0.01 on z
        let mut rng = Pcg64::new(31);
        (0..n)
            .map(|_| {
                let a = rng.normal() * 3.0;
                let b = rng.normal();
                let c = rng.normal() * 0.1;
                let s = std::f64::consts::FRAC_1_SQRT_2;
                vec![a * s + b * s, a * s - b * s, c]
            })
            .collect()
    }

    #[test]
    fn finds_dominant_axis() {
        let data = synth_anisotropic(5000);
        let pca = Pca::fit(&data, 2).unwrap();
        let c0 = &pca.components[0];
        let s = std::f64::consts::FRAC_1_SQRT_2;
        let dot = (c0[0] * s + c0[1] * s).abs();
        assert!(dot > 0.99, "dominant axis {c0:?}");
        assert!((pca.explained[0] - 9.0).abs() < 0.6);
        assert!((pca.explained[1] - 1.0).abs() < 0.2);
    }

    #[test]
    fn components_orthonormal() {
        let data = synth_anisotropic(2000);
        let pca = Pca::fit(&data, 2).unwrap();
        let dot: f64 = pca.components[0]
            .iter()
            .zip(&pca.components[1])
            .map(|(a, b)| a * b)
            .sum();
        assert!(dot.abs() < 1e-3, "dot {dot}");
        for c in &pca.components {
            let n: f64 = c.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn transform_centers() {
        let data = synth_anisotropic(1000);
        let pca = Pca::fit(&data, 2).unwrap();
        let mut acc = vec![0.0; 2];
        for row in &data {
            let t = pca.transform(row);
            acc[0] += t[0];
            acc[1] += t[1];
        }
        assert!(acc[0].abs() / 1000.0 < 1e-9);
        assert!(acc[1].abs() / 1000.0 < 1e-9);
    }

    #[test]
    fn too_few_rows_rejected() {
        assert!(Pca::fit(&[vec![1.0, 2.0]], 1).is_none());
    }
}
