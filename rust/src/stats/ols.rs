//! Ordinary least squares with a slope t-test.
//!
//! §IV-A-1 of the paper: model `n^f = f(n^r)` with OLS and use a t-test on
//! the slope to decide whether finished throughput still responds to batch
//! occupancy (not saturated) or has hit `n_limit` (saturated). §IV-A-2 uses
//! the same machinery for `m^u = g(n^r)` to extrapolate `gpu_memory`.

use super::tdist::t_test_p_value;

#[derive(Debug, Clone, Copy)]
pub struct OlsFit {
    pub intercept: f64,
    pub slope: f64,
    pub r_squared: f64,
    /// standard error of the slope
    pub slope_se: f64,
    /// t statistic of the slope against H0: slope == 0
    pub t_stat: f64,
    /// two-sided p-value of the slope t-test
    pub p_value: f64,
    pub n: usize,
}

impl OlsFit {
    pub fn predict(&self, x: f64) -> f64 {
        self.intercept + self.slope * x
    }

    /// Is the linear relationship significant at level `alpha`?
    pub fn significant(&self, alpha: f64) -> bool {
        self.p_value < alpha
    }
}

/// Fit y = a + b·x. Returns None for degenerate inputs (n < 3 or zero
/// x-variance), which callers treat as "no significant relationship".
pub fn fit(xs: &[f64], ys: &[f64]) -> Option<OlsFit> {
    let n = xs.len();
    if n != ys.len() || n < 3 {
        return None;
    }
    let nf = n as f64;
    let mx = xs.iter().sum::<f64>() / nf;
    let my = ys.iter().sum::<f64>() / nf;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx < 1e-12 {
        return None;
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (intercept + slope * x);
            e * e
        })
        .sum();
    let r_squared = if syy > 1e-12 {
        1.0 - ss_res / syy
    } else {
        0.0
    };
    let df = nf - 2.0;
    let mse = ss_res / df.max(1.0);
    let slope_se = (mse / sxx).sqrt();
    let t_stat = if slope_se > 1e-300 {
        slope / slope_se
    } else {
        f64::INFINITY
    };
    let p_value = if t_stat.is_infinite() {
        0.0
    } else {
        t_test_p_value(t_stat, df)
    };
    Some(OlsFit {
        intercept,
        slope,
        r_squared,
        slope_se,
        t_stat,
        p_value,
        n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_line() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let f = fit(&xs, &ys).unwrap();
        assert!((f.slope - 0.5).abs() < 1e-10);
        assert!((f.intercept - 3.0).abs() < 1e-8);
        assert!(f.r_squared > 0.999_99);
        assert!(f.significant(0.01));
    }

    #[test]
    fn noisy_flat_relationship_is_insignificant() {
        let mut rng = Pcg64::new(9);
        let xs: Vec<f64> = (0..200).map(|i| (i % 40) as f64).collect();
        let ys: Vec<f64> = (0..200).map(|_| 10.0 + rng.normal()).collect();
        let f = fit(&xs, &ys).unwrap();
        assert!(!f.significant(0.01), "p={}", f.p_value);
        assert!(f.slope.abs() < 0.1);
    }

    #[test]
    fn noisy_sloped_relationship_is_significant() {
        let mut rng = Pcg64::new(10);
        let xs: Vec<f64> = (0..200).map(|i| (i % 40) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.8 * x + rng.normal() * 2.0).collect();
        let f = fit(&xs, &ys).unwrap();
        assert!(f.significant(0.001));
        assert!((f.slope - 0.8).abs() < 0.1);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(fit(&[1.0, 2.0], &[1.0, 2.0]).is_none());
        assert!(fit(&[5.0; 10], &(0..10).map(|i| i as f64).collect::<Vec<_>>()).is_none());
    }

    #[test]
    fn prop_prediction_at_mean_is_mean() {
        crate::util::prop::check("ols passes through (x̄,ȳ)", 60, |g| {
            let n = g.usize_in(3, 60);
            let xs: Vec<f64> = (0..n).map(|_| g.f64_in(-50.0, 50.0)).collect();
            let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + g.f64_in(-1.0, 1.0)).collect();
            if let Some(f) = fit(&xs, &ys) {
                let mx = xs.iter().sum::<f64>() / n as f64;
                let my = ys.iter().sum::<f64>() / n as f64;
                crate::util::prop::ensure_close(f.predict(mx), my, 1e-9, "ŷ(x̄)")?;
            }
            Ok(())
        });
    }
}
