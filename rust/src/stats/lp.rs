//! Linear programming: a dense two-phase simplex for small problems, plus
//! the bounded integer search used for the replica plan of §IV-A-4 (eq. 8).
//!
//! The replica problem is tiny (one variable per GPU type, a handful of
//! constraints), so exactness matters more than scale: we solve the LP
//! relaxation with simplex and then do an exhaustive search in the integer
//! box around it, keeping the feasible integer point with the best
//! objective.

/// Minimize c·x subject to A·x ≤ b, x ≥ 0. Dense standard-form simplex
/// (Bland's rule, so no cycling). Returns `None` if infeasible/unbounded.
pub fn simplex_min(c: &[f64], a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    // maximize -c·x
    let neg_c: Vec<f64> = c.iter().map(|v| -v).collect();
    simplex_max(&neg_c, a, b)
}

/// Maximize c·x subject to A·x ≤ b, x ≥ 0.
pub fn simplex_max(c: &[f64], a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let m = a.len();
    let n = c.len();
    if b.iter().any(|&bi| bi < 0.0) {
        // Our callers only produce b ≥ 0 (capacities); keep phase-1-free.
        return None;
    }
    // tableau: m rows × (n + m + 1); slack basis
    let mut t = vec![vec![0.0; n + m + 1]; m + 1];
    for i in 0..m {
        for j in 0..n {
            t[i][j] = a[i][j];
        }
        t[i][n + i] = 1.0;
        t[i][n + m] = b[i];
    }
    for j in 0..n {
        t[m][j] = -c[j];
    }
    let mut basis: Vec<usize> = (n..n + m).collect();

    for _iter in 0..10_000 {
        // entering: Bland — smallest index with negative reduced cost
        let mut pivot_col = None;
        for j in 0..n + m {
            if t[m][j] < -1e-9 {
                pivot_col = Some(j);
                break;
            }
        }
        let Some(pc) = pivot_col else { break };
        // leaving: min ratio, Bland tie-break
        let mut pivot_row = None;
        let mut best = f64::INFINITY;
        for i in 0..m {
            if t[i][pc] > 1e-9 {
                let ratio = t[i][n + m] / t[i][pc];
                if ratio < best - 1e-12
                    || (ratio < best + 1e-12
                        && pivot_row.map(|r| basis[r] > basis[i]).unwrap_or(false))
                {
                    best = ratio;
                    pivot_row = Some(i);
                }
            }
        }
        let Some(pr) = pivot_row else {
            return None; // unbounded
        };
        // pivot
        let piv = t[pr][pc];
        for v in t[pr].iter_mut() {
            *v /= piv;
        }
        for i in 0..=m {
            if i != pr {
                let factor = t[i][pc];
                if factor.abs() > 1e-12 {
                    for j in 0..n + m + 1 {
                        t[i][j] -= factor * t[pr][j];
                    }
                }
            }
        }
        basis[pr] = pc;
    }

    let mut x = vec![0.0; n];
    for (i, &bv) in basis.iter().enumerate() {
        if bv < n {
            x[bv] = t[i][n + m];
        }
    }
    Some(x)
}

/// Integer refinement: search the box `[max(0, ⌊x*⌋−1), ⌈x*⌉+1]^n` around
/// the LP relaxation optimum for the best feasible integer point.
/// `feasible` must check every original constraint; `objective` is
/// minimized.
pub fn integer_refine(
    relaxed: &[f64],
    upper: &[usize],
    feasible: impl Fn(&[usize]) -> bool,
    objective: impl Fn(&[usize]) -> f64,
) -> Option<Vec<usize>> {
    let n = relaxed.len();
    let lo: Vec<usize> = relaxed
        .iter()
        .map(|&x| (x.floor() as isize - 1).max(0) as usize)
        .collect();
    let hi: Vec<usize> = relaxed
        .iter()
        .zip(upper)
        .map(|(&x, &u)| ((x.ceil() as usize) + 1).min(u))
        .collect();
    let mut best: Option<(f64, Vec<usize>)> = None;
    let mut cur = lo.clone();
    loop {
        if feasible(&cur) {
            let obj = objective(&cur);
            if best.as_ref().map(|(b, _)| obj < *b).unwrap_or(true) {
                best = Some((obj, cur.clone()));
            }
        }
        // odometer increment
        let mut k = 0;
        loop {
            if k == n {
                return best.map(|(_, v)| v);
            }
            if cur[k] < hi[k] {
                cur[k] += 1;
                break;
            }
            cur[k] = lo[k];
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn textbook_max() {
        // max 3x + 5y st x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6), obj 36
        let x = simplex_max(
            &[3.0, 5.0],
            &[
                vec![1.0, 0.0],
                vec![0.0, 2.0],
                vec![3.0, 2.0],
            ],
            &[4.0, 12.0, 18.0],
        )
        .unwrap();
        assert!((x[0] - 2.0).abs() < 1e-7 && (x[1] - 6.0).abs() < 1e-7, "{x:?}");
    }

    #[test]
    fn min_with_cover_constraint() {
        // min 2x + 3y st −x − y ≤ −4 is not expressible (b<0); model as
        // maximize coverage instead: the config module always poses
        // capacity-style (≤) constraints, mirrored here.
        // min 2x+3y st x ≤ 10, y ≤ 10 and we want x+y ≥ 4 handled by
        // integer_refine feasibility.
        let relaxed = simplex_min(&[2.0, 3.0], &[vec![1.0, 0.0], vec![0.0, 1.0]], &[10.0, 10.0])
            .unwrap();
        // LP relaxation of pure-min with no lower bound is 0; integer
        // refinement with the cover constraint pushes it up
        let best = integer_refine(
            &[relaxed[0].max(4.0), relaxed[1]],
            &[10, 10],
            |x| x[0] + x[1] >= 4,
            |x| 2.0 * x[0] as f64 + 3.0 * x[1] as f64,
        )
        .unwrap();
        assert_eq!(best, vec![4, 0]);
    }

    #[test]
    fn unbounded_detected() {
        // max x with no constraints that bound it
        assert!(simplex_max(&[1.0], &[vec![0.0]], &[5.0]).is_none());
    }

    #[test]
    fn integer_refine_respects_upper() {
        let best = integer_refine(
            &[2.4, 0.3],
            &[2, 5],
            |x| x[0] * 2 + x[1] >= 5,
            |x| x[0] as f64 + x[1] as f64,
        )
        .unwrap();
        assert!(best[0] <= 2);
        assert!(best[0] * 2 + best[1] >= 5);
        assert_eq!(best.iter().sum::<usize>(), 3); // (2,1)
    }

    #[test]
    fn prop_simplex_respects_constraints() {
        crate::util::prop::check("simplex feasibility", 40, |g| {
            let n = g.usize_in(1, 4);
            let m = g.usize_in(1, 4);
            let c: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 5.0)).collect();
            let a: Vec<Vec<f64>> = (0..m)
                .map(|_| (0..n).map(|_| g.f64_in(0.0, 3.0)).collect())
                .collect();
            let b: Vec<f64> = (0..m).map(|_| g.f64_in(1.0, 20.0)).collect();
            if let Some(x) = simplex_max(&c, &a, &b) {
                for i in 0..m {
                    let lhs: f64 = (0..n).map(|j| a[i][j] * x[j]).sum();
                    crate::util::prop::ensure(
                        lhs <= b[i] + 1e-6,
                        format!("constraint {i} violated: {lhs} > {}", b[i]),
                    )?;
                }
                for &xi in &x {
                    crate::util::prop::ensure(xi >= -1e-9, "negative x")?;
                }
            }
            Ok(())
        });
    }
}
