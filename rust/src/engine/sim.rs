//! Deterministic, artifact-free [`StreamEngine`]: same slot/continuous-
//! batching semantics as the real PJRT [`super::Engine`], but tokens come
//! from a hash of the prompt instead of compiled-model logits. This is the
//! engine the gateway integration tests (and `enova serve-http --engine
//! sim`) run against, so the serving stack is exercisable in environments
//! without the AOT artifacts — and so closed-loop tests are byte-for-byte
//! reproducible.

use super::{
    Completion, EngineRequest, FinishReason, ReconfigOutcome, StepOutput, StreamEngine, TokenDelta,
};
use crate::cluster::snapshot::{fnv1a64, EngineSnapshot, SnapReader, SnapWriter, SnapshotError};
use crate::metrics::Frame;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Vocabulary the simulated model "speaks": the decoded stream is readable
/// so curl demos look like generation, not noise.
const WORDS: [&str; 16] = [
    "the", "service", "scales", "replicas", "under", "bursty", "traffic", "while", "latency",
    "stays", "stable", "and", "throughput", "improves", "per", "gpu",
];

/// Hard ceiling on the simulated slot count: reconfiguration clamps here,
/// mirroring the real engine's compiled batch width.
pub const MAX_SIM_SLOTS: usize = 4096;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimEngineConfig {
    /// admitted concurrency (slot count)
    pub max_num_seqs: usize,
    /// output-token cap per request
    pub max_tokens: usize,
    /// artificial compute time per decode iteration (0 = instant); lets
    /// tests hold requests in flight long enough to observe admission
    /// control and streaming
    pub step_delay: Duration,
}

impl Default for SimEngineConfig {
    fn default() -> Self {
        SimEngineConfig {
            max_num_seqs: 8,
            max_tokens: 64,
            step_delay: Duration::ZERO,
        }
    }
}

struct SimSlot {
    req: EngineRequest,
    seed: u64,
    tokens: Vec<i32>,
    text: String,
    budget: usize,
    first_token_at: Option<f64>,
}

pub struct SimEngine {
    pub cfg: SimEngineConfig,
    /// effective concurrency ceiling (live-reconfigurable). The slot
    /// vector only ever grows: shrinking lowers this ceiling while
    /// occupied slots above it drain to completion.
    limit: usize,
    /// live gpu_memory fraction; scales the simulated KV budget
    gpu_memory: f64,
    slots: Vec<Option<SimSlot>>,
    pending: VecDeque<EngineRequest>,
    clock: Instant,
    arrived: u64,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl SimEngine {
    pub fn new(cfg: SimEngineConfig) -> SimEngine {
        let b = cfg.max_num_seqs.max(1);
        SimEngine {
            cfg,
            limit: b,
            gpu_memory: 0.9,
            slots: (0..b).map(|_| None).collect(),
            pending: VecDeque::new(),
            clock: Instant::now(),
            arrived: 0,
        }
    }

    fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    /// fnv1a over the deterministic-generation invariants: a snapshot from
    /// an engine with a different token budget or step timing would be a
    /// *different* engine, so restore refuses it.
    pub fn config_fingerprint(cfg: &SimEngineConfig) -> u64 {
        let mut w = SnapWriter::new();
        w.put_str("sim");
        w.put_u64(cfg.max_tokens as u64);
        w.put_u64(cfg.step_delay.as_nanos() as u64);
        fnv1a64(&w.into_bytes())
    }

    fn decode_payload(snap: &EngineSnapshot) -> Result<(SimEngineConfig, f64, u64), SnapshotError> {
        if snap.engine_kind != "sim" {
            return Err(SnapshotError::KindMismatch {
                found: snap.engine_kind.clone(),
                expected: "sim".into(),
            });
        }
        let mut r = SnapReader::new(&snap.payload);
        let max_tokens = r.take_u64()? as usize;
        let step_delay = Duration::from_nanos(r.take_u64()?);
        let arrived = r.take_u64()?;
        let cfg = SimEngineConfig {
            max_num_seqs: snap.max_num_seqs.clamp(1, MAX_SIM_SLOTS),
            max_tokens,
            step_delay,
        };
        let expected = SimEngine::config_fingerprint(&cfg);
        if snap.fingerprint != expected {
            return Err(SnapshotError::FingerprintMismatch {
                found: snap.fingerprint,
                expected,
            });
        }
        Ok((cfg, snap.gpu_memory.clamp(0.05, 0.98), arrived))
    }

    /// Build a serving-ready engine directly from a snapshot — the
    /// restore-beats-cold-spawn path: no spawner, no init work, just the
    /// checkpointed config + counters. Fail-closed on any mismatch.
    pub fn from_snapshot(snap: &EngineSnapshot) -> Result<SimEngine, SnapshotError> {
        let (cfg, gpu_memory, arrived) = SimEngine::decode_payload(snap)?;
        let mut engine = SimEngine::new(cfg);
        engine.gpu_memory = gpu_memory;
        engine.arrived = arrived;
        Ok(engine)
    }
}

impl StreamEngine for SimEngine {
    fn submit(&mut self, prompt: &str, max_new: usize) -> u64 {
        let id = self.arrived;
        self.arrived += 1;
        self.pending.push_back(EngineRequest {
            id,
            prompt: prompt.to_string(),
            max_new,
            arrival: self.now(),
        });
        id
    }

    fn step_stream(&mut self) -> Result<StepOutput> {
        // 1. admission — only into slots under the live ceiling; slots
        // above it (occupied before a shrink) drain but never refill
        for slot in self.slots.iter_mut().take(self.limit) {
            if slot.is_some() {
                continue;
            }
            let Some(req) = self.pending.pop_front() else { break };
            let budget = self.cfg.max_tokens.min(req.max_new.max(1)).max(1);
            let seed = fnv1a(req.prompt.as_bytes());
            *slot = Some(SimSlot {
                req,
                seed,
                tokens: Vec::new(),
                text: String::new(),
                budget,
                first_token_at: None,
            });
        }
        if self.slots.iter().all(|s| s.is_none()) {
            return Ok(StepOutput::default());
        }

        // 2. one "decode iteration"
        if !self.cfg.step_delay.is_zero() {
            std::thread::sleep(self.cfg.step_delay);
        }
        let now = self.now();
        let mut out = StepOutput::default();
        for slot in self.slots.iter_mut() {
            let finished = match slot {
                Some(s) => {
                    let idx = s.tokens.len();
                    let word = WORDS[((s.seed as usize).wrapping_add(idx)) % WORDS.len()];
                    let tok = 3 + ((s.seed as usize).wrapping_add(idx) % 509) as i32;
                    let text = format!("{word} ");
                    s.tokens.push(tok);
                    s.text.push_str(&text);
                    if s.first_token_at.is_none() {
                        s.first_token_at = Some(now);
                    }
                    let done = s.tokens.len() >= s.budget;
                    out.deltas.push(TokenDelta {
                        id: s.req.id,
                        token: tok,
                        text,
                        index: idx,
                        finish: done.then_some(FinishReason::MaxTokens),
                    });
                    done
                }
                None => false,
            };
            if finished {
                let s = slot.take().unwrap();
                out.finished.push(Completion {
                    id: s.req.id,
                    text: s.text,
                    tokens: s.tokens,
                    prompt_tokens: s.req.prompt.len(),
                    arrival: s.req.arrival,
                    first_token_at: s.first_token_at.unwrap_or(now),
                    finished_at: now,
                    finish_reason: FinishReason::MaxTokens,
                });
            }
        }
        Ok(out)
    }

    fn idle(&self) -> bool {
        self.pending.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    fn pending_len(&self) -> usize {
        self.pending.len()
    }

    fn running_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn capacity(&self) -> usize {
        self.limit
    }

    fn reconfigure(&mut self, max_num_seqs: usize, gpu_memory: f64) -> Result<ReconfigOutcome> {
        // the sim has no compiled batch width; MAX_SIM_SLOTS stands in as
        // the hard ceiling so a wild recommendation cannot balloon the
        // slot vector (the real Engine clamps to lm.spec.batch)
        let target = max_num_seqs.clamp(1, MAX_SIM_SLOTS);
        if target > self.slots.len() {
            self.slots.resize_with(target, || None);
        }
        self.limit = target;
        self.gpu_memory = gpu_memory.clamp(0.05, 0.98);
        Ok(ReconfigOutcome {
            max_num_seqs: self.limit,
            gpu_memory: self.gpu_memory,
        })
    }

    /// The sim's deterministic state is its config + counters: generation
    /// is a pure function of the prompt hash, so in-flight work needs no
    /// serializing — it drains on the source replica before retirement
    /// (the migration contract), and the restored engine regenerates any
    /// resubmitted prompt byte-for-byte.
    fn snapshot(&self) -> Result<EngineSnapshot> {
        let mut w = SnapWriter::new();
        w.put_u64(self.cfg.max_tokens as u64);
        w.put_u64(self.cfg.step_delay.as_nanos() as u64);
        w.put_u64(self.arrived);
        Ok(EngineSnapshot::new(
            "sim",
            self.limit,
            self.gpu_memory,
            SimEngine::config_fingerprint(&self.cfg),
            w.into_bytes(),
        ))
    }

    fn restore(&mut self, snapshot: &EngineSnapshot) -> Result<()> {
        let (cfg, gpu_memory, arrived) =
            SimEngine::decode_payload(snapshot).map_err(|e| anyhow::anyhow!("{e}"))?;
        // fingerprint verified against the snapshot's own recorded config;
        // it must also match THIS engine's invariants or the restore would
        // silently change what the replica generates
        let mine = SimEngine::config_fingerprint(&self.cfg);
        if snapshot.fingerprint != mine {
            return Err(anyhow::anyhow!(
                "{}",
                SnapshotError::FingerprintMismatch {
                    found: snapshot.fingerprint,
                    expected: mine,
                }
            ));
        }
        self.cfg = cfg;
        self.gpu_memory = gpu_memory;
        self.arrived = arrived;
        let target = cfg.max_num_seqs;
        if target > self.slots.len() {
            self.slots.resize_with(target, || None);
        }
        self.limit = target;
        Ok(())
    }

    fn frame(&self, finished_in_window: f64, arrived_in_window: f64, mean_latency: f64) -> Frame {
        let b = self.limit.max(1);
        let kv_used: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.req.prompt.len() / 4 + s.tokens.len())
            .sum();
        // simulated KV budget scales with the live gpu_memory fraction
        let kv_cap = (b * 256) as f64 * (self.gpu_memory / 0.9);
        Frame {
            n_finished: finished_in_window,
            n_running: self.running_len() as f64,
            n_arriving: arrived_in_window,
            n_pending: self.pending.len() as f64,
            t_request: mean_latency,
            mem_util: (0.35 + 0.6 * kv_used as f64 / kv_cap).min(1.0),
            // clamped: slots draining above a shrunk limit would push the
            // ratio past 1 and skew a freshly-calibrating detector
            gpu_util: (self.running_len() as f64 / b as f64).min(1.0),
            kv_util: (kv_used as f64 / kv_cap).min(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(engine: &mut SimEngine) -> Vec<Completion> {
        let mut done = Vec::new();
        while !engine.idle() {
            done.extend(engine.step_stream().unwrap().finished);
        }
        done
    }

    #[test]
    fn deterministic_for_same_prompt() {
        let mut a = SimEngine::new(SimEngineConfig::default());
        let mut b = SimEngine::new(SimEngineConfig::default());
        a.submit("what is autoscaling?", 6);
        b.submit("what is autoscaling?", 6);
        let ca = drain(&mut a);
        let cb = drain(&mut b);
        assert_eq!(ca[0].text, cb[0].text);
        assert_eq!(ca[0].tokens, cb[0].tokens);
        assert_eq!(ca[0].tokens.len(), 6);
    }

    #[test]
    fn deltas_stream_token_by_token() {
        let mut e = SimEngine::new(SimEngineConfig::default());
        let id = e.submit("p", 3);
        let mut text = String::new();
        let mut finishes = 0;
        while !e.idle() {
            let out = e.step_stream().unwrap();
            for d in &out.deltas {
                assert_eq!(d.id, id);
                text.push_str(&d.text);
                if d.finish.is_some() {
                    finishes += 1;
                }
            }
        }
        assert_eq!(finishes, 1, "exactly one finishing delta");
        let mut again = SimEngine::new(SimEngineConfig::default());
        again.submit("p", 3);
        assert_eq!(drain(&mut again)[0].text, text, "deltas concat == text");
    }

    #[test]
    fn overflow_waits_in_pending() {
        let mut e = SimEngine::new(SimEngineConfig {
            max_num_seqs: 2,
            max_tokens: 4,
            step_delay: Duration::ZERO,
        });
        for i in 0..5 {
            e.submit(&format!("req {i}"), 4);
        }
        assert_eq!(e.pending_len(), 5);
        let out = e.step_stream().unwrap();
        assert_eq!(e.running_len() + out.finished.len(), 2);
        assert!(e.pending_len() >= 3);
        assert_eq!(drain(&mut e).len() + out.finished.len(), 5);
    }

    #[test]
    fn reconfigure_grows_capacity_live() {
        let mut e = SimEngine::new(SimEngineConfig {
            max_num_seqs: 2,
            max_tokens: 4,
            step_delay: Duration::ZERO,
        });
        for i in 0..6 {
            e.submit(&format!("req {i}"), 4);
        }
        let _ = e.step_stream().unwrap();
        assert_eq!(e.running_len(), 2);
        let out = e.reconfigure(4, 0.95).unwrap();
        assert_eq!(out.max_num_seqs, 4);
        assert!((out.gpu_memory - 0.95).abs() < 1e-12);
        assert_eq!(e.capacity(), 4);
        let _ = e.step_stream().unwrap();
        assert_eq!(e.running_len(), 4, "new slots admit immediately");
        assert_eq!(drain(&mut e).len(), 6);
    }

    #[test]
    fn reconfigure_shrink_drains_above_capacity_work() {
        let mut e = SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 8,
            step_delay: Duration::ZERO,
        });
        for i in 0..4 {
            e.submit(&format!("held {i}"), 8);
        }
        let _ = e.step_stream().unwrap();
        assert_eq!(e.running_len(), 4);
        // shrink to 1 while 4 are mid-generation: nothing is dropped
        let out = e.reconfigure(1, 0.9).unwrap();
        assert_eq!(out.max_num_seqs, 1);
        assert_eq!(e.capacity(), 1);
        // queue more work than the new ceiling admits at once
        for i in 0..3 {
            e.submit(&format!("queued {i}"), 2);
        }
        let mut peak_after_drain = 0usize;
        let mut done = Vec::new();
        while !e.idle() {
            done.extend(e.step_stream().unwrap().finished);
            // once the pre-shrink cohort drained, occupancy obeys the limit
            if done.len() >= 4 {
                peak_after_drain = peak_after_drain.max(e.running_len());
            }
        }
        assert_eq!(done.len(), 7, "every request completed: {}", done.len());
        assert!(
            peak_after_drain <= 1,
            "post-drain occupancy exceeded the shrunk limit: {peak_after_drain}"
        );
    }

    #[test]
    fn snapshot_restores_an_identical_engine() {
        let mut src = SimEngine::new(SimEngineConfig {
            max_num_seqs: 3,
            max_tokens: 32,
            step_delay: Duration::ZERO,
        });
        src.submit("warm it up", 4);
        let _ = drain(&mut src);
        let _ = src.reconfigure(5, 0.8).unwrap();
        let snap = src.snapshot().unwrap();
        assert_eq!(snap.engine_kind, "sim");
        assert_eq!(snap.max_num_seqs, 5);

        // the frame survives the wire
        let decoded =
            crate::cluster::snapshot::EngineSnapshot::decode(&snap.encode()).unwrap();
        let mut restored = SimEngine::from_snapshot(&decoded).unwrap();
        assert_eq!(restored.capacity(), 5);
        assert_eq!(restored.cfg.max_tokens, 32);

        // determinism carries over: same prompt, same completion
        src.submit("does the clone agree?", 6);
        restored.submit("does the clone agree?", 6);
        let a = drain(&mut src);
        let b = drain(&mut restored);
        assert_eq!(a[0].text, b[0].text);
        assert_eq!(a[0].tokens, b[0].tokens);
    }

    #[test]
    fn restore_refuses_a_foreign_kind() {
        let snap = crate::cluster::snapshot::EngineSnapshot::new("lm", 4, 0.9, 1, Vec::new());
        assert!(SimEngine::from_snapshot(&snap).is_err());
        let mut e = SimEngine::new(SimEngineConfig::default());
        assert!(e.restore(&snap).is_err());
    }

    #[test]
    fn frame_reports_utilization() {
        let mut e = SimEngine::new(SimEngineConfig::default());
        e.submit("hello", 8);
        let _ = e.step_stream().unwrap();
        let f = e.frame(1.0, 2.0, 0.25);
        assert_eq!(f.n_running, 1.0);
        assert_eq!(f.n_finished, 1.0);
        assert_eq!(f.t_request, 0.25);
        assert!(f.gpu_util > 0.0 && f.kv_util > 0.0 && f.mem_util <= 1.0);
    }
}
