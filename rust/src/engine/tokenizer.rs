//! Byte-level tokenizer for the tiny served model (vocab 512: specials +
//! raw bytes). Real deployments plug a BPE here; the serving layer only
//! needs encode/decode + special ids.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
const BYTE_BASE: i32 = 3;

#[derive(Debug, Clone, Copy)]
pub struct Tokenizer {
    pub vocab: usize,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        assert!(vocab >= 256 + BYTE_BASE as usize);
        Tokenizer { vocab }
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        out.extend(text.bytes().map(|b| b as i32 + BYTE_BASE));
        out
    }

    /// Encode and clamp to at most `max_len` tokens (keeping the tail,
    /// which carries the actual question in chat-style prompts).
    pub fn encode_clamped(&self, text: &str, max_len: usize) -> Vec<i32> {
        let mut toks = self.encode(text);
        if toks.len() > max_len {
            let start = toks.len() - (max_len - 1);
            let mut clamped = vec![BOS];
            clamped.extend_from_slice(&toks[start..]);
            toks = clamped;
        }
        toks
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t >= BYTE_BASE && t < BYTE_BASE + 256)
            .map(|&t| (t - BYTE_BASE) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_eos(&self, token: i32) -> bool {
        token == EOS
    }

    /// The raw byte a token encodes, if it is a byte token (specials and
    /// out-of-range ids return `None`).
    pub fn byte_of(&self, token: i32) -> Option<u8> {
        if (BYTE_BASE..BYTE_BASE + 256).contains(&token) {
            Some((token - BYTE_BASE) as u8)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tk = Tokenizer::new(512);
        let text = "Solve 2+2, carefully.";
        let toks = tk.encode(text);
        assert_eq!(toks[0], BOS);
        assert_eq!(tk.decode(&toks), text);
    }

    #[test]
    fn clamping_keeps_tail() {
        let tk = Tokenizer::new(512);
        let text = "x".repeat(300);
        let toks = tk.encode_clamped(&text, 64);
        assert_eq!(toks.len(), 64);
        assert_eq!(toks[0], BOS);
        assert_eq!(tk.decode(&toks).len(), 63);
    }

    #[test]
    fn byte_of_classifies_tokens() {
        let tk = Tokenizer::new(512);
        assert_eq!(tk.byte_of(BYTE_BASE), Some(0));
        assert_eq!(tk.byte_of(BYTE_BASE + 255), Some(255));
        assert_eq!(tk.byte_of(EOS), None);
        assert_eq!(tk.byte_of(BYTE_BASE + 256), None);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let tk = Tokenizer::new(512);
        for t in tk.encode("áé≈\u{1F600}") {
            assert!((0..512).contains(&t));
        }
    }
}
