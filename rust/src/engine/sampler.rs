//! Token sampling: greedy and temperature softmax.

use crate::util::rng::Pcg64;

pub struct Sampler {
    rng: Pcg64,
}

impl Sampler {
    pub fn new(seed: u64) -> Sampler {
        Sampler {
            rng: Pcg64::new(seed),
        }
    }

    pub fn sample(&mut self, logits: &[f32], temperature: f64) -> i32 {
        if temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        let inv_t = 1.0 / temperature as f32;
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f32> = logits.iter().map(|&x| ((x - m) * inv_t).exp()).collect();
        let z: f32 = probs.iter().sum();
        if z <= 0.0 || !z.is_finite() {
            return argmax(logits) as i32;
        }
        for p in probs.iter_mut() {
            *p /= z;
        }
        let mut x = self.rng.f64() as f32;
        for (i, &p) in probs.iter().enumerate() {
            x -= p;
            if x <= 0.0 {
                return i as i32;
            }
        }
        (probs.len() - 1) as i32
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(1);
        assert_eq!(s.sample(&[0.1, 5.0, -2.0], 0.0), 1);
    }

    #[test]
    fn temperature_respects_distribution() {
        let mut s = Sampler::new(2);
        let logits = [0.0f32, 2.0, 0.0];
        let n = 5000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[s.sample(&logits, 1.0) as usize] += 1;
        }
        // p1 = e²/(e²+2) ≈ 0.787
        let p1 = counts[1] as f64 / n as f64;
        assert!((p1 - 0.787).abs() < 0.03, "p1 {p1}");
    }

    #[test]
    fn high_temperature_flattens() {
        let mut s = Sampler::new(3);
        let logits = [0.0f32, 1.0, 0.0];
        let n = 6000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[s.sample(&logits, 50.0) as usize] += 1;
        }
        for c in counts {
            let p = c as f64 / n as f64;
            assert!((p - 1.0 / 3.0).abs() < 0.05, "p {p}");
        }
    }
}
