//! The in-tree serving engine: iteration-level continuous batching (Orca)
//! over the PJRT-compiled tiny LM. This is the *real* request path — the
//! same coordinator logic the simulator models, but executing actual
//! compiled-model steps on the CPU PJRT client.
//!
//! The engine is synchronous and slot-based: the compiled decode program
//! has a fixed batch width `B` (the replica's `max_num_seqs` ceiling);
//! requests occupy slots, join/leave between iterations, and inactive
//! slots are masked with `seq_len = 0`.

pub mod sampler;
pub mod sim;
pub mod tokenizer;

use crate::cluster::snapshot::EngineSnapshot;
use crate::metrics::Frame;
#[cfg(feature = "xla-runtime")]
use crate::runtime::lm::LmRuntime;
use anyhow::Result;
#[cfg(feature = "xla-runtime")]
use sampler::Sampler;
#[cfg(feature = "xla-runtime")]
use std::collections::VecDeque;
#[cfg(feature = "xla-runtime")]
use tokenizer::Tokenizer;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    /// admitted concurrency; clamped to the compiled batch width
    pub max_num_seqs: usize,
    /// output-token cap per request (the Table I knob)
    pub max_tokens: usize,
    /// sampling temperature; 0 = greedy
    pub temperature: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_num_seqs: 8,
            max_tokens: 64,
            temperature: 0.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct EngineRequest {
    pub id: u64,
    pub prompt: String,
    /// request-specific output cap (min-ed with the engine's max_tokens)
    pub max_new: usize,
    /// wall-clock arrival, seconds (engine-relative)
    pub arrival: f64,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub arrival: f64,
    pub first_token_at: f64,
    pub finished_at: f64,
    pub finish_reason: FinishReason,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
}

impl FinishReason {
    /// OpenAI wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "stop",
            FinishReason::MaxTokens => "length",
        }
    }
}

/// One token produced for one request during a single engine step — the
/// unit the gateway turns into an SSE `chat.completion.chunk`.
#[derive(Debug, Clone)]
pub struct TokenDelta {
    pub id: u64,
    pub token: i32,
    /// decoded text of just this token ("" for specials like EOS)
    pub text: String,
    /// 0-based position in the request's output
    pub index: usize,
    /// set on the request's last delta
    pub finish: Option<FinishReason>,
}

/// Result of one iteration of a step-wise engine.
#[derive(Debug, Clone, Default)]
pub struct StepOutput {
    pub deltas: Vec<TokenDelta>,
    pub finished: Vec<Completion>,
}

/// What a live capacity mutation actually applied, after clamping to the
/// engine's hard limits (compiled batch width, sane gpu_memory range).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReconfigOutcome {
    pub max_num_seqs: usize,
    pub gpu_memory: f64,
}

/// Step-wise completion engine: what the gateway's replica workers drive.
/// Implemented by the real PJRT [`Engine`] and by the artifact-free
/// [`sim::SimEngine`] used in tests and offline demos.
pub trait StreamEngine {
    fn submit(&mut self, prompt: &str, max_new: usize) -> u64;
    /// Admit pending work and run one decode iteration; returns per-token
    /// deltas plus any completions that finished this step.
    fn step_stream(&mut self) -> Result<StepOutput>;
    fn idle(&self) -> bool;
    fn pending_len(&self) -> usize;
    fn running_len(&self) -> usize;
    /// Concurrency the engine can actually run (its slot count). The
    /// gateway's replica workers use this to backpressure admission:
    /// jobs wait in the worker queue — where queue-time budgets apply —
    /// instead of piling into an unbounded engine pending queue.
    fn capacity(&self) -> usize;
    /// Mutate live capacity — the Fig. 6 knobs (`max_num_seqs`,
    /// `gpu_memory`) re-derived by the configuration module — without a
    /// relaunch and without dropping work. Shrinking below current
    /// occupancy must *drain*: requests already running above the new
    /// ceiling finish naturally; only new admissions see the lower limit.
    /// Returns what was actually applied after clamping.
    fn reconfigure(&mut self, max_num_seqs: usize, gpu_memory: f64) -> Result<ReconfigOutcome>;
    /// Snapshot the Table II monitoring frame.
    fn frame(&self, finished_in_window: f64, arrived_in_window: f64, mean_latency: f64) -> Frame;
    /// Checkpoint the post-init engine (config knobs, allocator/KV arena
    /// shape, deterministic counters — not in-flight work, which drains on
    /// the source) into a versioned binary snapshot, so a replica can be
    /// spawned from it in milliseconds instead of re-running init. Engines
    /// that cannot checkpoint keep the default refusal.
    fn snapshot(&self) -> Result<EngineSnapshot> {
        Err(anyhow::anyhow!("this engine does not support snapshots"))
    }
    /// Rebuild engine state from a snapshot. **Fail-closed**: a version,
    /// kind or config-fingerprint mismatch must be an error (the caller
    /// falls back to a cold spawn), never a partial restore.
    fn restore(&mut self, snapshot: &EngineSnapshot) -> Result<()> {
        let _ = snapshot;
        Err(anyhow::anyhow!("this engine does not support snapshot restore"))
    }
}

/// Pop every complete UTF-8 sequence off the front of `pending`, replacing
/// definitively-invalid byte runs with U+FFFD (the same policy as
/// `from_utf8_lossy`). A trailing incomplete sequence stays buffered for
/// the next token. Keeps streamed deltas valid UTF-8 even though the
/// byte-level LM emits multi-byte characters one token at a time.
#[cfg(feature = "xla-runtime")]
fn drain_valid_utf8(pending: &mut Vec<u8>) -> String {
    let mut out = String::new();
    loop {
        match std::str::from_utf8(pending) {
            Ok(valid) => {
                out.push_str(valid);
                pending.clear();
                return out;
            }
            Err(e) => {
                let valid = e.valid_up_to();
                out.push_str(std::str::from_utf8(&pending[..valid]).unwrap());
                match e.error_len() {
                    Some(bad) => {
                        out.push('\u{fffd}');
                        pending.drain(..valid + bad);
                    }
                    None => {
                        // incomplete tail: keep buffering
                        pending.drain(..valid);
                        return out;
                    }
                }
            }
        }
    }
}

#[cfg(feature = "xla-runtime")]
struct Slot {
    req: EngineRequest,
    generated: Vec<i32>,
    seq_len: usize,
    first_token_at: Option<f64>,
    budget: usize,
    /// bytes of a partially-emitted UTF-8 character (streaming)
    utf8_pending: Vec<u8>,
}

#[cfg(feature = "xla-runtime")]
pub struct Engine {
    pub lm: LmRuntime,
    pub cfg: EngineConfig,
    /// live gpu_memory fraction (the Fig. 6 knob): scales the KV budget
    /// the monitoring frame reports against
    gpu_memory: f64,
    tokenizer: Tokenizer,
    sampler: Sampler,
    slots: Vec<Option<Slot>>,
    pending: VecDeque<EngineRequest>,
    clock: std::time::Instant,
    arrived: u64,
    finished_count: u64,
    // scratch reused across steps (perf: no per-step allocation)
    tokens_buf: Vec<i32>,
    lens_buf: Vec<i32>,
}

#[cfg(feature = "xla-runtime")]
impl Engine {
    pub fn new(lm: LmRuntime, cfg: EngineConfig, seed: u64) -> Engine {
        let b = lm.spec.batch;
        let vocab = lm.spec.vocab;
        Engine {
            tokenizer: Tokenizer::new(vocab),
            sampler: Sampler::new(seed),
            slots: (0..b).map(|_| None).collect(),
            pending: VecDeque::new(),
            clock: std::time::Instant::now(),
            arrived: 0,
            finished_count: 0,
            tokens_buf: vec![0; b],
            lens_buf: vec![0; b],
            gpu_memory: 0.9,
            lm,
            cfg,
        }
    }

    pub fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    /// fnv1a over the invariants a snapshot must agree on to restore into
    /// this engine: the compiled program shape (batch width, vocab,
    /// context length) — the parts that cannot be changed live.
    pub fn config_fingerprint(&self) -> u64 {
        use crate::cluster::snapshot::{fnv1a64, SnapWriter};
        let mut w = SnapWriter::new();
        w.put_str("lm");
        w.put_u64(self.lm.spec.batch as u64);
        w.put_u64(self.lm.spec.vocab as u64);
        w.put_u64(self.lm.spec.max_seq as u64);
        fnv1a64(&w.into_bytes())
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn submit(&mut self, prompt: &str, max_new: usize) -> u64 {
        let id = self.arrived;
        self.arrived += 1;
        self.pending.push_back(EngineRequest {
            id,
            prompt: prompt.to_string(),
            max_new,
            arrival: self.now(),
        });
        id
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn running_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn idle(&self) -> bool {
        self.pending.is_empty() && self.running_len() == 0
    }

    /// Slots the engine can actually occupy: the configured concurrency
    /// clamped to the compiled batch width.
    pub fn capacity(&self) -> usize {
        self.cfg.max_num_seqs.min(self.slots.len()).max(1)
    }

    /// Admit pending requests into free slots (prefill each); then run one
    /// decode iteration; returns completions that finished this step.
    /// Skips per-token delta assembly — the decode hot loop stays
    /// allocation-free for non-streaming callers.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        Ok(self.step_inner(false)?.finished)
    }

    /// Step-wise variant of [`Engine::step`]: additionally reports every
    /// token sampled this iteration so callers can stream incrementally.
    pub fn step_stream(&mut self) -> Result<StepOutput> {
        self.step_inner(true)
    }

    fn step_inner(&mut self, collect_deltas: bool) -> Result<StepOutput> {
        let b = self.lm.spec.batch;
        let effective_slots = self.cfg.max_num_seqs.min(b);

        // 1. admission + prefill
        for slot_idx in 0..effective_slots {
            if self.slots[slot_idx].is_some() {
                continue;
            }
            let Some(req) = self.pending.pop_front() else { break };
            let budget_cap = self.cfg.max_tokens.min(req.max_new.max(1));
            let max_prompt = self.lm.spec.max_seq.saturating_sub(budget_cap.min(16)).max(8);
            let prompt_toks = self
                .tokenizer
                .encode_clamped(&req.prompt, max_prompt);
            self.lm.prefill(&prompt_toks, slot_idx)?;
            let seq_len = prompt_toks.len();
            let budget = budget_cap.min(self.lm.spec.max_seq - seq_len - 1).max(1);
            self.slots[slot_idx] = Some(Slot {
                req,
                generated: Vec::new(),
                seq_len,
                first_token_at: None,
                budget,
                utf8_pending: Vec::new(),
            });
        }

        if self.running_len() == 0 {
            return Ok(StepOutput::default());
        }

        // 2. sample next token per active slot from current logits
        let all_logits = self.lm.all_logits()?;
        let vocab = self.lm.spec.vocab;
        self.tokens_buf.fill(0);
        self.lens_buf.fill(0);
        let mut deltas = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(s) = slot {
                let logits = &all_logits[i * vocab..(i + 1) * vocab];
                let tok = self.sampler.sample(logits, self.cfg.temperature);
                s.generated.push(tok);
                self.tokens_buf[i] = tok;
                self.lens_buf[i] = s.seq_len as i32;
                if collect_deltas {
                    let text = match self.tokenizer.byte_of(tok) {
                        Some(byte) => {
                            s.utf8_pending.push(byte);
                            drain_valid_utf8(&mut s.utf8_pending)
                        }
                        None => String::new(), // specials contribute no text
                    };
                    deltas.push(TokenDelta {
                        id: s.req.id,
                        token: tok,
                        text,
                        index: s.generated.len() - 1,
                        finish: None,
                    });
                }
            }
        }

        // 3. one decode iteration appends those tokens & produces new logits
        self.lm.decode(&self.tokens_buf, &self.lens_buf)?;
        let now = self.now();

        // 4. retire finished slots
        let mut done = Vec::new();
        let mut tails: Vec<(u64, String)> = Vec::new();
        for slot in self.slots.iter_mut() {
            let finished = match slot {
                Some(s) => {
                    if s.first_token_at.is_none() {
                        s.first_token_at = Some(now);
                    }
                    s.seq_len += 1;
                    let last = *s.generated.last().unwrap();
                    let eos = self.tokenizer.is_eos(last);
                    let out_of_budget = s.generated.len() >= s.budget;
                    let out_of_ctx = s.seq_len + 1 >= self.lm.spec.max_seq;
                    eos || out_of_budget || out_of_ctx
                }
                None => false,
            };
            if finished {
                let s = slot.take().unwrap();
                let eos_stopped = self.tokenizer.is_eos(*s.generated.last().unwrap());
                self.finished_count += 1;
                if collect_deltas && !s.utf8_pending.is_empty() {
                    // generation ended mid-character: flush lossily, like
                    // the full decode below does for the same bytes
                    tails.push((
                        s.req.id,
                        String::from_utf8_lossy(&s.utf8_pending).into_owned(),
                    ));
                }
                done.push(Completion {
                    id: s.req.id,
                    text: self.tokenizer.decode(&s.generated),
                    prompt_tokens: s.req.prompt.len(),
                    tokens: s.generated,
                    arrival: s.req.arrival,
                    first_token_at: s.first_token_at.unwrap_or(now),
                    finished_at: now,
                    finish_reason: if eos_stopped {
                        FinishReason::Eos
                    } else {
                        FinishReason::MaxTokens
                    },
                });
            }
        }
        if collect_deltas {
            for c in &done {
                if let Some(d) = deltas.iter_mut().find(|d| d.id == c.id) {
                    d.finish = Some(c.finish_reason);
                    if let Some((_, tail)) = tails.iter().find(|(id, _)| *id == c.id) {
                        d.text.push_str(tail);
                    }
                }
            }
        }
        Ok(StepOutput {
            deltas,
            finished: done,
        })
    }

    /// Drive the engine until all submitted work completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Apply a live capacity mutation: `max_num_seqs` is clamped to the
    /// compiled batch width (the program's slot count is fixed at AOT
    /// time), `gpu_memory` to the practical vLLM range. Shrinking never
    /// drops work — the admission loop simply stops refilling slots above
    /// the new ceiling while occupied ones decode to completion.
    pub fn reconfigure(&mut self, max_num_seqs: usize, gpu_memory: f64) -> ReconfigOutcome {
        self.cfg.max_num_seqs = max_num_seqs.clamp(1, self.lm.spec.batch);
        self.gpu_memory = gpu_memory.clamp(0.05, 0.98);
        ReconfigOutcome {
            max_num_seqs: self.cfg.max_num_seqs,
            gpu_memory: self.gpu_memory,
        }
    }

    /// Snapshot the Table II frame for monitoring.
    pub fn frame(&self, finished_in_window: f64, arrived_in_window: f64, mean_latency: f64) -> Frame {
        let b = self.cfg.max_num_seqs.min(self.lm.spec.batch).max(1);
        let kv_used: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.seq_len)
            .sum();
        // the KV budget scales with the configured gpu_memory fraction
        let kv_cap = (b * self.lm.spec.max_seq) as f64 * (self.gpu_memory / 0.9);
        Frame {
            n_finished: finished_in_window,
            n_running: self.running_len() as f64,
            n_arriving: arrived_in_window,
            n_pending: self.pending.len() as f64,
            t_request: mean_latency,
            mem_util: (0.35 + 0.6 * kv_used as f64 / kv_cap).min(1.0),
            // clamped: slots draining above a shrunk max_num_seqs would
            // push the ratio past 1
            gpu_util: if self.running_len() > 0 {
                (self.running_len() as f64 / b as f64).min(1.0)
            } else {
                0.0
            },
            kv_util: (kv_used as f64 / kv_cap).min(1.0),
        }
    }
}

#[cfg(all(test, feature = "xla-runtime"))]
mod tests {
    use super::drain_valid_utf8;

    #[test]
    fn utf8_draining_holds_incomplete_sequences() {
        // "é" = 0xC3 0xA9 arrives one byte per decode step
        let mut pending = Vec::new();
        pending.push(0xC3);
        assert_eq!(drain_valid_utf8(&mut pending), "");
        assert_eq!(pending, vec![0xC3]);
        pending.push(0xA9);
        assert_eq!(drain_valid_utf8(&mut pending), "é");
        assert!(pending.is_empty());
    }

    #[test]
    fn utf8_draining_mixes_ascii_and_multibyte() {
        // "a☕" byte-by-byte: ascii flushes immediately, the 3-byte char
        // only once complete
        let bytes = "a☕b".as_bytes();
        let mut pending = Vec::new();
        let mut out = String::new();
        for &b in bytes {
            pending.push(b);
            out.push_str(&drain_valid_utf8(&mut pending));
        }
        assert_eq!(out, "a☕b");
        assert!(pending.is_empty());
    }

    #[test]
    fn utf8_draining_replaces_definitively_invalid_bytes() {
        // stray continuation byte can never start a character
        let mut pending = vec![0x80, b'x'];
        assert_eq!(drain_valid_utf8(&mut pending), "\u{fffd}x");
        assert!(pending.is_empty());
    }
}

#[cfg(feature = "xla-runtime")]
impl StreamEngine for Engine {
    fn submit(&mut self, prompt: &str, max_new: usize) -> u64 {
        Engine::submit(self, prompt, max_new)
    }

    fn step_stream(&mut self) -> Result<StepOutput> {
        Engine::step_stream(self)
    }

    fn idle(&self) -> bool {
        Engine::idle(self)
    }

    fn pending_len(&self) -> usize {
        Engine::pending_len(self)
    }

    fn running_len(&self) -> usize {
        Engine::running_len(self)
    }

    fn capacity(&self) -> usize {
        Engine::capacity(self)
    }

    fn reconfigure(&mut self, max_num_seqs: usize, gpu_memory: f64) -> Result<ReconfigOutcome> {
        Ok(Engine::reconfigure(self, max_num_seqs, gpu_memory))
    }

    fn frame(&self, finished_in_window: f64, arrived_in_window: f64, mean_latency: f64) -> Frame {
        Engine::frame(self, finished_in_window, arrived_in_window, mean_latency)
    }

    /// The PJRT snapshot records the config + compiled-program shape
    /// (weights re-map from the artifact directory on restore — the
    /// expensive part a restore skips is tokenizer/sampler/slot init and
    /// the config derivation, not the mmap).
    fn snapshot(&self) -> Result<EngineSnapshot> {
        use crate::cluster::snapshot::SnapWriter;
        let mut w = SnapWriter::new();
        w.put_u64(self.cfg.max_tokens as u64);
        w.put_f64(self.cfg.temperature);
        w.put_u64(self.arrived);
        w.put_u64(self.finished_count);
        Ok(EngineSnapshot::new(
            "lm",
            self.cfg.max_num_seqs,
            self.gpu_memory,
            self.config_fingerprint(),
            w.into_bytes(),
        ))
    }

    fn restore(&mut self, snapshot: &EngineSnapshot) -> Result<()> {
        use crate::cluster::snapshot::{SnapReader, SnapshotError};
        if snapshot.engine_kind != "lm" {
            return Err(anyhow::anyhow!(
                "{}",
                SnapshotError::KindMismatch {
                    found: snapshot.engine_kind.clone(),
                    expected: "lm".into(),
                }
            ));
        }
        let expected = self.config_fingerprint();
        if snapshot.fingerprint != expected {
            return Err(anyhow::anyhow!(
                "{}",
                SnapshotError::FingerprintMismatch {
                    found: snapshot.fingerprint,
                    expected,
                }
            ));
        }
        let mut r = SnapReader::new(&snapshot.payload);
        let max_tokens = r.take_u64().map_err(|e| anyhow::anyhow!("{e}"))? as usize;
        let temperature = r.take_f64().map_err(|e| anyhow::anyhow!("{e}"))?;
        let arrived = r.take_u64().map_err(|e| anyhow::anyhow!("{e}"))?;
        let finished = r.take_u64().map_err(|e| anyhow::anyhow!("{e}"))?;
        self.cfg.max_tokens = max_tokens;
        self.cfg.temperature = temperature;
        self.arrived = arrived;
        self.finished_count = finished;
        Engine::reconfigure(self, snapshot.max_num_seqs, snapshot.gpu_memory);
        Ok(())
    }
}
