//! GPU device catalog with public-spec roofline parameters.
//!
//! The experiments' absolute numbers come from these rooflines, so they are
//! taken from vendor datasheets (dense BF16 TFLOPS without sparsity, HBM/
//! GDDR peak bandwidth). The simulator applies efficiency factors on top
//! (see `replica.rs`), which is where calibration lives.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub mem_bytes: f64,
    /// dense bf16/fp16 peak, FLOP/s
    pub flops: f64,
    /// memory bandwidth, bytes/s
    pub mem_bw: f64,
    /// hourly price in USD (public cloud list-ish; used for cost scoring)
    pub usd_per_hour: f64,
}

pub const A100_80G: GpuSpec = GpuSpec {
    name: "A100-80G",
    mem_bytes: 80.0e9,
    flops: 312.0e12,
    mem_bw: 2039.0e9,
    usd_per_hour: 3.67,
};

pub const RTX4090_24G: GpuSpec = GpuSpec {
    name: "RTX4090-24G",
    mem_bytes: 24.0e9,
    flops: 165.0e12,
    mem_bw: 1008.0e9,
    usd_per_hour: 0.74,
};

pub const H100_80G: GpuSpec = GpuSpec {
    name: "H100-80G",
    mem_bytes: 80.0e9,
    flops: 989.0e12,
    mem_bw: 3350.0e9,
    usd_per_hour: 5.93,
};

pub const L40S_48G: GpuSpec = GpuSpec {
    name: "L40S-48G",
    mem_bytes: 48.0e9,
    flops: 362.0e12,
    mem_bw: 864.0e9,
    usd_per_hour: 1.96,
};

pub const CATALOG: [&GpuSpec; 4] = [&A100_80G, &RTX4090_24G, &H100_80G, &L40S_48G];

pub fn by_name(name: &str) -> Option<&'static GpuSpec> {
    CATALOG.iter().copied().find(|g| g.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(by_name("a100-80g").unwrap().name, "A100-80G");
        assert!(by_name("tpu-v5").is_none());
    }

    #[test]
    fn sane_rooflines() {
        for g in CATALOG {
            assert!(g.flops > 1e14);
            assert!(g.mem_bw > 5e11);
            assert!(g.mem_bytes >= 24e9);
            // arithmetic intensity at the roofline knee should be O(100)
            let knee = g.flops / g.mem_bw;
            assert!((50.0..700.0).contains(&knee), "{}: knee {knee}", g.name);
        }
    }
}
