//! LLM model cards: the architectural numbers that drive the roofline
//! (parameter bytes, active parameters for MoE, KV bytes per token).
//! Matches the five models of the paper's Fig. 4 (Llama-2 7/13/70B,
//! Mistral-7B, Mixtral-8x7B) plus the tiny in-repo model served for real.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelCard {
    pub name: &'static str,
    /// total parameters
    pub params: f64,
    /// parameters touched per token (≠ params for MoE)
    pub active_params: f64,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// weight bytes per parameter (fp16 serving)
    pub bytes_per_param: f64,
    /// model context limit
    pub max_context: usize,
    /// max output tokens the raw model supports (BASELINE max_tokens)
    pub max_model_tokens: usize,
}

impl ModelCard {
    /// KV-cache bytes per token: 2 (K,V) · layers · kv_dim · 2 bytes.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64 * (self.n_kv_heads * self.head_dim) as f64 * 2.0
    }

    pub fn weight_bytes(&self) -> f64 {
        self.params * self.bytes_per_param
    }
}

pub const LLAMA2_7B: ModelCard = ModelCard {
    name: "L-7B",
    params: 6.74e9,
    active_params: 6.74e9,
    n_layers: 32,
    d_model: 4096,
    n_kv_heads: 32,
    head_dim: 128,
    bytes_per_param: 2.0,
    max_context: 4096,
    max_model_tokens: 4096,
};

pub const LLAMA2_13B: ModelCard = ModelCard {
    name: "L-13B",
    params: 13.0e9,
    active_params: 13.0e9,
    n_layers: 40,
    d_model: 5120,
    n_kv_heads: 40,
    head_dim: 128,
    bytes_per_param: 2.0,
    max_context: 4096,
    max_model_tokens: 4096,
};

pub const LLAMA2_70B: ModelCard = ModelCard {
    name: "L-70B",
    params: 69.0e9,
    active_params: 69.0e9,
    n_layers: 80,
    d_model: 8192,
    n_kv_heads: 8, // GQA
    head_dim: 128,
    bytes_per_param: 2.0,
    max_context: 4096,
    max_model_tokens: 4096,
};

pub const MISTRAL_7B: ModelCard = ModelCard {
    name: "M-7B",
    params: 7.24e9,
    active_params: 7.24e9,
    n_layers: 32,
    d_model: 4096,
    n_kv_heads: 8, // GQA
    head_dim: 128,
    bytes_per_param: 2.0,
    max_context: 8192,
    max_model_tokens: 8192,
};

pub const MIXTRAL_8X7B: ModelCard = ModelCard {
    name: "M-8x7B",
    params: 46.7e9,
    active_params: 12.9e9, // 2-of-8 experts
    n_layers: 32,
    d_model: 4096,
    n_kv_heads: 8,
    head_dim: 128,
    bytes_per_param: 2.0,
    max_context: 8192,
    max_model_tokens: 8192,
};

/// The in-repo tiny model actually served via PJRT (see artifacts/).
pub const TINY_LM: ModelCard = ModelCard {
    name: "tiny-lm",
    params: 1.13e6,
    active_params: 1.13e6,
    n_layers: 4,
    d_model: 128,
    n_kv_heads: 4,
    head_dim: 32,
    bytes_per_param: 4.0, // f32 artifacts
    max_context: 128,
    max_model_tokens: 128,
};

pub const FIG4_MODELS: [&ModelCard; 5] = [
    &LLAMA2_7B,
    &LLAMA2_13B,
    &LLAMA2_70B,
    &MISTRAL_7B,
    &MIXTRAL_8X7B,
];

pub fn by_name(name: &str) -> Option<&'static ModelCard> {
    [&LLAMA2_7B, &LLAMA2_13B, &LLAMA2_70B, &MISTRAL_7B, &MIXTRAL_8X7B, &TINY_LM]
        .into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_sanity() {
        // Llama-2 7B: 2·32·4096·2 = 512 KiB per token
        assert_eq!(LLAMA2_7B.kv_bytes_per_token(), 524_288.0);
        // GQA models store 4× less than MHA at same width
        assert!(MISTRAL_7B.kv_bytes_per_token() * 4.0 == LLAMA2_7B.kv_bytes_per_token());
        // 70B with GQA: 2·80·1024·2 = 320 KiB
        assert_eq!(LLAMA2_70B.kv_bytes_per_token(), 327_680.0);
    }

    #[test]
    fn weight_bytes() {
        assert!((LLAMA2_7B.weight_bytes() - 13.48e9).abs() < 1e8);
        assert!(MIXTRAL_8X7B.active_params < MIXTRAL_8X7B.params);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("m-8x7b").unwrap().name, "M-8x7B");
        assert!(by_name("gpt-5").is_none());
    }
}
