//! Iteration-level replica simulator: one LLM service instance (model ×
//! GPU group × service config) processing a request stream with
//! vLLM-style continuous batching and paged-KV admission.
//!
//! This is the substitution for the paper's A100/4090 testbed (DESIGN.md
//! §Substitutions). Step latency follows the serving roofline:
//!
//!   decode(B, ctx) = max( weights/BW + B·ctx·kv_bytes/BW ,
//!                         2·active_params·B / FLOPS ) + overhead
//!   prefill(P)     = 2·active_params·P / (FLOPS·prefill_eff) + overhead
//!
//! with per-group bandwidth/compute scaled by `parallel_size` and constant
//! efficiency factors (measured vLLM-class systems hit ~60-80% of roofline;
//! the factors are documented constants, not tuned per-experiment). The
//! phenomena the paper builds on — throughput plateau at the compute knee,
//! latency explosion when pending queues form, KV-capacity admission — all
//! emerge from this structure rather than being scripted.

use super::gpu::GpuSpec;
use super::modelcard::ModelCard;
use crate::metrics::Frame;

/// Service configuration knobs (Table I) of one replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceConfig {
    pub max_num_seqs: usize,
    /// fraction of device memory the service may use (vLLM gpu_memory_utilization)
    pub gpu_memory: f64,
    /// output-token cap applied to every request
    pub max_tokens: usize,
    /// tensor-parallel group size
    pub parallel_size: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        // vLLM defaults-ish: the paper's "Default" baseline uses
        // max_num_seqs 8 / max_tokens 256 (Table III).
        ServiceConfig {
            max_num_seqs: 8,
            gpu_memory: 0.9,
            max_tokens: 256,
            parallel_size: 1,
        }
    }
}

/// One user request entering the replica.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: u64,
    pub arrival: f64,
    pub prompt_len: usize,
    /// tokens the request *wants* to generate (stop-criteria length)
    pub gen_target: usize,
    /// task community (workload family), for per-community stats
    pub community: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct FinishedRequest {
    pub id: u64,
    pub arrival: f64,
    pub first_token: f64,
    pub finish: f64,
    pub prompt_len: usize,
    pub out_len: usize,
    /// stopped by max_tokens before reaching gen_target
    pub truncated: bool,
    pub community: usize,
}

impl FinishedRequest {
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// The paper's latency metric: execution time / output length (s/token).
    pub fn normalized_latency(&self) -> f64 {
        self.latency() / self.out_len.max(1) as f64
    }
}

#[derive(Debug, Default, Clone)]
pub struct SimResult {
    pub finished: Vec<FinishedRequest>,
    /// requests dropped by HTTP timeout while pending
    pub timed_out: usize,
    /// requests still in flight / queued at horizon
    pub unserved: usize,
    pub preemptions: usize,
    /// requests not completed within the horizon (pending + in-flight +
    /// not-yet-arrived), with original arrival times — lets the autoscaler
    /// resume a workload across a reconfiguration/relaunch boundary
    pub leftover: Vec<Request>,
    /// per-second metric frames (Table II)
    pub frames: Vec<(f64, Frame)>,
    pub horizon: f64,
    pub output_tokens: u64,
    /// number of GPUs used (parallel_size)
    pub gpus_used: usize,
}

impl SimResult {
    /// Paper throughput metric: output tokens / GPU / second.
    pub fn throughput_per_gpu(&self) -> f64 {
        if self.horizon <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / self.gpus_used.max(1) as f64 / self.horizon
    }

    pub fn mean_normalized_latency(&self) -> f64 {
        if self.finished.is_empty() {
            return f64::INFINITY;
        }
        self.finished
            .iter()
            .map(|f| f.normalized_latency())
            .sum::<f64>()
            / self.finished.len() as f64
    }

    pub fn p99_latency(&self) -> f64 {
        if self.finished.is_empty() {
            return f64::INFINITY;
        }
        let lats: Vec<f64> = self.finished.iter().map(|f| f.latency()).collect();
        crate::stats::descriptive::quantile(&lats, 0.99)
    }

    pub fn finished_rps(&self) -> f64 {
        self.finished.len() as f64 / self.horizon.max(1e-9)
    }
}

/// Engine-measured efficiency factors (documented, global).
const BW_EFF: f64 = 0.75; // achieved fraction of peak HBM bandwidth
const COMPUTE_EFF: f64 = 0.55; // achieved fraction of peak dense FLOPS (decode GEMMs)
const PREFILL_EFF: f64 = 0.70; // prefill GEMMs are larger → better MXU/TC util
const STEP_OVERHEAD: f64 = 4.0e-3; // scheduler + kernel-launch floor per iteration
const TP_SYNC_OVERHEAD: f64 = 0.8e-3; // per extra TP rank per step (all-reduce)
/// HTTP client timeout: pending longer than this fails the request (the
/// Fig. 1 "service down" mode).
pub const HTTP_TIMEOUT: f64 = 120.0;

struct RunningReq {
    req: Request,
    first_token: Option<f64>,
    generated: usize,
    target: usize,
    ctx_len: usize,
}

pub struct Replica {
    pub gpu: &'static GpuSpec,
    pub model: &'static ModelCard,
    pub cfg: ServiceConfig,
}

impl Replica {
    pub fn new(gpu: &'static GpuSpec, model: &'static ModelCard, cfg: ServiceConfig) -> Replica {
        Replica { gpu, model, cfg }
    }

    /// Does the model fit at all with this config?
    pub fn fits(&self) -> bool {
        self.kv_budget_bytes() > self.model.kv_bytes_per_token() * 64.0
    }

    /// Total KV-cache byte budget across the TP group.
    pub fn kv_budget_bytes(&self) -> f64 {
        let p = self.cfg.parallel_size.max(1) as f64;
        let usable = self.gpu.mem_bytes * self.cfg.gpu_memory * p;
        // activations/workspace overhead ~3% of weights
        usable - self.model.weight_bytes() * 1.03
    }

    fn group_bw(&self) -> f64 {
        self.gpu.mem_bw * self.cfg.parallel_size.max(1) as f64 * BW_EFF
    }

    fn group_flops(&self, eff: f64) -> f64 {
        self.gpu.flops * self.cfg.parallel_size.max(1) as f64 * eff
    }

    fn step_overhead(&self) -> f64 {
        STEP_OVERHEAD + TP_SYNC_OVERHEAD * (self.cfg.parallel_size.saturating_sub(1)) as f64
    }

    /// One decode iteration for `batch` sequences with total context tokens
    /// `ctx_total` across the batch.
    pub fn decode_step_time(&self, batch: usize, ctx_total: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let weights = self.model.weight_bytes() / self.group_bw();
        let kv = ctx_total as f64 * self.model.kv_bytes_per_token() / self.group_bw();
        let compute =
            2.0 * self.model.active_params * batch as f64 / self.group_flops(COMPUTE_EFF);
        (weights + kv).max(compute) + self.step_overhead()
    }

    /// Prefill `prompt_tokens` (possibly several prompts batched).
    pub fn prefill_time(&self, prompt_tokens: usize) -> f64 {
        2.0 * self.model.active_params * prompt_tokens as f64
            / self.group_flops(PREFILL_EFF)
            + self.step_overhead()
    }

    /// Upper-bound decode throughput (tokens/s) at batch size `b` and mean
    /// context `ctx` — used by benches to locate the plateau analytically.
    pub fn decode_throughput(&self, b: usize, ctx: usize) -> f64 {
        b as f64 / self.decode_step_time(b, b * ctx)
    }

    /// Simulate a pre-routed arrival stream until `horizon` seconds.
    pub fn simulate(&self, mut arrivals: Vec<Request>, horizon: f64) -> SimResult {
        arrivals.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let kv_budget = self.kv_budget_bytes();
        let kv_per_tok = self.model.kv_bytes_per_token();
        let weight_frac = (self.model.weight_bytes() * 1.03)
            / (self.gpu.mem_bytes * self.cfg.parallel_size.max(1) as f64);

        let mut result = SimResult {
            horizon,
            gpus_used: self.cfg.parallel_size.max(1),
            ..Default::default()
        };
        if kv_budget <= 0.0 {
            // model doesn't fit: everything times out
            result.timed_out = arrivals.len();
            return result;
        }

        let mut pending: std::collections::VecDeque<Request> = Default::default();
        let mut running: Vec<RunningReq> = Vec::new();
        let mut next_arrival = 0usize;
        let mut t = 0.0f64;

        // per-second metric accumulation
        let n_buckets = horizon.ceil() as usize;
        let mut acc: Vec<FrameAcc> = vec![FrameAcc::default(); n_buckets];

        while t < horizon {
            // 1. pull in arrivals up to t
            while next_arrival < arrivals.len() && arrivals[next_arrival].arrival <= t {
                let r = arrivals[next_arrival];
                bucket(&mut acc, r.arrival).arrived += 1.0;
                pending.push_back(r);
                next_arrival += 1;
            }

            // 2. expire pending requests past the HTTP timeout
            while let Some(front) = pending.front() {
                if t - front.arrival > HTTP_TIMEOUT {
                    pending.pop_front();
                    result.timed_out += 1;
                } else {
                    break;
                }
            }

            // 3. admission: fill free batch slots while KV fits
            let mut kv_used: f64 = running
                .iter()
                .map(|r| r.ctx_len as f64 * kv_per_tok)
                .sum();
            let mut admitted_tokens = 0usize;
            while running.len() < self.cfg.max_num_seqs {
                let Some(front) = pending.front() else { break };
                let projected =
                    (front.prompt_len + front.gen_target.min(self.cfg.max_tokens)) as f64
                        * kv_per_tok;
                if kv_used + projected > kv_budget {
                    break;
                }
                let req = pending.pop_front().unwrap();
                kv_used += req.prompt_len as f64 * kv_per_tok;
                admitted_tokens += req.prompt_len;
                let target = req.gen_target.min(self.cfg.max_tokens).max(1);
                running.push(RunningReq {
                    req,
                    first_token: None,
                    generated: 0,
                    target,
                    ctx_len: req.prompt_len,
                });
            }

            // 4. advance: prefill admitted prompts, else decode, else idle
            let step_time;
            if admitted_tokens > 0 {
                step_time = self.prefill_time(admitted_tokens);
            } else if !running.is_empty() {
                let ctx_total: usize = running.iter().map(|r| r.ctx_len).sum();
                step_time = self.decode_step_time(running.len(), ctx_total);
                let now = t + step_time;
                let mut finished_idx = Vec::new();
                for (i, r) in running.iter_mut().enumerate() {
                    if r.first_token.is_none() {
                        r.first_token = Some(now);
                    }
                    r.generated += 1;
                    r.ctx_len += 1;
                    result.output_tokens += 1;
                    if r.generated >= r.target {
                        finished_idx.push(i);
                    }
                }
                for &i in finished_idx.iter().rev() {
                    let r = running.swap_remove(i);
                    bucket(&mut acc, now.min(horizon - 1e-9)).finished_lat
                        .push(now - r.req.arrival);
                    result.finished.push(FinishedRequest {
                        id: r.req.id,
                        arrival: r.req.arrival,
                        first_token: r.first_token.unwrap_or(now),
                        finish: now,
                        prompt_len: r.req.prompt_len,
                        out_len: r.generated,
                        truncated: r.generated >= self.cfg.max_tokens
                            && r.req.gen_target > self.cfg.max_tokens,
                        community: r.req.community,
                    });
                }
            } else {
                // idle: jump to next arrival (or finish)
                step_time = if next_arrival < arrivals.len() {
                    (arrivals[next_arrival].arrival - t).max(1e-6)
                } else {
                    break;
                };
            }

            // 5. KV overflow → preempt the most recent request (vLLM-style)
            let kv_now: f64 = running.iter().map(|r| r.ctx_len as f64 * kv_per_tok).sum();
            if kv_now > kv_budget && running.len() > 1 {
                let victim = running.pop().unwrap();
                result.preemptions += 1;
                pending.push_front(victim.req);
            }

            // 6. metrics for the elapsed interval
            let kv_util = (kv_now / kv_budget).min(1.0);
            let busy = !running.is_empty() || admitted_tokens > 0;
            let ctx_total: usize = running.iter().map(|r| r.ctx_len).sum();
            let gpu_util = if busy {
                let compute = 2.0 * self.model.active_params * running.len().max(1) as f64
                    / self.group_flops(1.0);
                (compute / self.decode_step_time(running.len().max(1), ctx_total)).min(1.0)
            } else {
                0.0
            };
            let mem_util = (weight_frac * (1.0 / self.cfg.gpu_memory).min(1.0)
                + kv_util * (1.0 - weight_frac))
                .min(1.0)
                * self.cfg.gpu_memory;
            let t_end = (t + step_time).min(horizon);
            let mut tt = t;
            while tt < t_end {
                let b = bucket(&mut acc, tt);
                b.running_samples.push(running.len() as f64);
                b.pending_samples.push(pending.len() as f64);
                b.kv_util.push(kv_util);
                b.gpu_util.push(if busy { gpu_util } else { 0.0 });
                b.mem_util.push(mem_util);
                tt = (tt.floor() + 1.0).max(tt + 1e-9);
            }

            t += step_time;
        }

        result.unserved = running.len() + pending.len() + (arrivals.len() - next_arrival);
        result.leftover = running
            .iter()
            .map(|r| r.req)
            .chain(pending.iter().copied())
            .chain(arrivals[next_arrival..].iter().copied())
            .collect();
        result.frames = acc
            .into_iter()
            .enumerate()
            .map(|(i, a)| (i as f64, a.into_frame()))
            .collect();
        result
    }
}

#[derive(Default, Clone)]
struct FrameAcc {
    arrived: f64,
    finished_lat: Vec<f64>,
    running_samples: Vec<f64>,
    pending_samples: Vec<f64>,
    kv_util: Vec<f64>,
    gpu_util: Vec<f64>,
    mem_util: Vec<f64>,
}

impl FrameAcc {
    fn into_frame(self) -> Frame {
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        Frame {
            n_finished: self.finished_lat.len() as f64,
            n_running: mean(&self.running_samples),
            n_arriving: self.arrived,
            n_pending: mean(&self.pending_samples),
            t_request: mean(&self.finished_lat),
            mem_util: mean(&self.mem_util),
            gpu_util: mean(&self.gpu_util),
            kv_util: mean(&self.kv_util),
        }
    }
}

fn bucket(acc: &mut [FrameAcc], t: f64) -> &mut FrameAcc {
    let idx = (t as usize).min(acc.len().saturating_sub(1));
    &mut acc[idx]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gpu::{A100_80G, RTX4090_24G};
    use crate::simulator::modelcard::{LLAMA2_70B, LLAMA2_7B};
    use crate::util::rng::Pcg64;

    fn poisson_arrivals(rps: f64, horizon: f64, seed: u64) -> Vec<Request> {
        let mut rng = Pcg64::new(seed);
        let mut t = 0.0;
        let mut out = Vec::new();
        let mut id = 0;
        while t < horizon {
            t += rng.exponential(rps);
            out.push(Request {
                id,
                arrival: t,
                prompt_len: 200 + rng.usize_in(0, 200),
                gen_target: 150 + rng.usize_in(0, 200),
                community: 0,
            });
            id += 1;
        }
        out
    }

    fn cfg(max_num_seqs: usize) -> ServiceConfig {
        ServiceConfig {
            max_num_seqs,
            gpu_memory: 0.9,
            max_tokens: 512,
            parallel_size: 1,
        }
    }

    #[test]
    fn roofline_orders_devices() {
        let a = Replica::new(&A100_80G, &LLAMA2_7B, cfg(64));
        let r = Replica::new(&RTX4090_24G, &LLAMA2_7B, cfg(64));
        assert!(a.decode_step_time(32, 32 * 500) < r.decode_step_time(32, 32 * 500));
        // bigger batch, longer step but higher throughput until the knee
        assert!(a.decode_step_time(64, 64 * 500) > a.decode_step_time(8, 8 * 500));
        assert!(a.decode_throughput(64, 500) > a.decode_throughput(8, 500));
    }

    #[test]
    fn throughput_plateaus_with_batch() {
        // Fig. 7 premise: finished-rate rises then flattens; memory keeps growing
        let low = Replica::new(&A100_80G, &LLAMA2_7B, cfg(8)).decode_throughput(8, 400);
        let mid = Replica::new(&A100_80G, &LLAMA2_7B, cfg(64)).decode_throughput(64, 400);
        let high = Replica::new(&A100_80G, &LLAMA2_7B, cfg(512)).decode_throughput(512, 400);
        assert!(mid > low * 3.0);
        assert!(high < mid * 2.5, "plateau expected: mid={mid} high={high}");
    }

    #[test]
    fn seventy_b_needs_tensor_parallel() {
        let single = Replica::new(&A100_80G, &LLAMA2_70B, cfg(16));
        assert!(!single.fits());
        let tp2 = Replica::new(
            &A100_80G,
            &LLAMA2_70B,
            ServiceConfig {
                parallel_size: 2,
                ..cfg(16)
            },
        );
        assert!(tp2.fits());
    }

    #[test]
    fn underload_finishes_everything() {
        let rep = Replica::new(&A100_80G, &LLAMA2_7B, cfg(64));
        let arrivals = poisson_arrivals(2.0, 120.0, 1);
        let n = arrivals.len();
        let res = rep.simulate(arrivals, 300.0);
        assert_eq!(res.timed_out, 0);
        assert!(res.finished.len() + res.unserved >= n - 1);
        assert!(res.finished.len() as f64 >= 0.9 * n as f64);
        // pending stays near zero in steady state
        let max_pending = res
            .frames
            .iter()
            .map(|(_, f)| f.n_pending)
            .fold(0.0, f64::max);
        assert!(max_pending < 20.0, "max pending {max_pending}");
    }

    #[test]
    fn overload_explodes_queue() {
        // Fig. 1: slightly past capacity, pending grows without bound
        let rep = Replica::new(&RTX4090_24G, &LLAMA2_7B, cfg(16));
        let res_over = rep.simulate(poisson_arrivals(40.0, 300.0, 2), 300.0);
        let tail_pending = res_over
            .frames
            .iter()
            .rev()
            .take(30)
            .map(|(_, f)| f.n_pending)
            .sum::<f64>()
            / 30.0;
        assert!(
            tail_pending > 50.0 || res_over.timed_out > 0,
            "overload should queue or time out (pending {tail_pending})"
        );
    }

    #[test]
    fn latencies_monotone_with_load() {
        let rep = Replica::new(&A100_80G, &LLAMA2_7B, cfg(48));
        let lo = rep.simulate(poisson_arrivals(1.0, 200.0, 3), 400.0);
        let hi = rep.simulate(poisson_arrivals(12.0, 200.0, 4), 400.0);
        assert!(lo.mean_normalized_latency() <= hi.mean_normalized_latency());
    }

    #[test]
    fn max_tokens_truncates() {
        let mut c = cfg(32);
        c.max_tokens = 64;
        let rep = Replica::new(&A100_80G, &LLAMA2_7B, c);
        let res = rep.simulate(poisson_arrivals(2.0, 60.0, 5), 200.0);
        assert!(res.finished.iter().all(|f| f.out_len <= 64));
        assert!(res.finished.iter().any(|f| f.truncated));
    }

    #[test]
    fn frames_cover_horizon() {
        let rep = Replica::new(&A100_80G, &LLAMA2_7B, cfg(16));
        let res = rep.simulate(poisson_arrivals(3.0, 50.0, 6), 100.0);
        assert_eq!(res.frames.len(), 100);
        let total_finished: f64 = res.frames.iter().map(|(_, f)| f.n_finished).sum();
        assert_eq!(total_finished as usize, res.finished.len());
    }
}
