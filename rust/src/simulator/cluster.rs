//! Cluster-level simulation: several replicas (possibly on heterogeneous
//! GPU types) behind the weighted load balancer of §IV-A-4. Arrivals are
//! split by routing weight, each replica simulates independently, and the
//! results merge into cluster-level throughput/latency — exactly how the
//! paper's multi-GPU experiments (Fig. 4, Table III weights column) are
//! structured.

use super::replica::{Replica, Request, SimResult};
use crate::util::rng::Pcg64;

pub struct ClusterSim {
    pub replicas: Vec<Replica>,
    /// routing weights (∝ per-replica n_limit); normalized internally
    pub weights: Vec<f64>,
}

#[derive(Debug, Default, Clone)]
pub struct ClusterResult {
    pub per_replica: Vec<SimResult>,
    pub horizon: f64,
}

impl ClusterResult {
    pub fn finished(&self) -> usize {
        self.per_replica.iter().map(|r| r.finished.len()).sum()
    }

    pub fn timed_out(&self) -> usize {
        self.per_replica.iter().map(|r| r.timed_out).sum()
    }

    pub fn total_gpus(&self) -> usize {
        self.per_replica.iter().map(|r| r.gpus_used).sum()
    }

    /// Paper throughput metric across the cluster: tokens/GPU/s.
    pub fn throughput_per_gpu(&self) -> f64 {
        let tokens: u64 = self.per_replica.iter().map(|r| r.output_tokens).sum();
        tokens as f64 / self.total_gpus().max(1) as f64 / self.horizon.max(1e-9)
    }

    pub fn mean_normalized_latency(&self) -> f64 {
        let all: Vec<f64> = self
            .per_replica
            .iter()
            .flat_map(|r| r.finished.iter().map(|f| f.normalized_latency()))
            .collect();
        if all.is_empty() {
            f64::INFINITY
        } else {
            all.iter().sum::<f64>() / all.len() as f64
        }
    }

    /// Fraction of all issued requests that completed within the horizon.
    pub fn completion_ratio(&self, issued: usize) -> f64 {
        self.finished() as f64 / issued.max(1) as f64
    }
}

impl ClusterSim {
    pub fn new(replicas: Vec<Replica>, weights: Vec<f64>) -> ClusterSim {
        assert_eq!(replicas.len(), weights.len());
        ClusterSim { replicas, weights }
    }

    /// Route `arrivals` by weighted sampling and simulate each replica.
    pub fn simulate(&self, arrivals: &[Request], horizon: f64, seed: u64) -> ClusterResult {
        let mut rng = Pcg64::new(seed ^ 0xc1u64);
        let total_w: f64 = self.weights.iter().sum();
        let mut streams: Vec<Vec<Request>> = vec![Vec::new(); self.replicas.len()];
        for req in arrivals {
            let mut x = rng.f64() * total_w;
            let mut chosen = self.replicas.len() - 1;
            for (i, w) in self.weights.iter().enumerate() {
                x -= w;
                if x <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            streams[chosen].push(*req);
        }
        let per_replica = self
            .replicas
            .iter()
            .zip(streams)
            .map(|(rep, stream)| rep.simulate(stream, horizon))
            .collect();
        ClusterResult {
            per_replica,
            horizon,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gpu::{A100_80G, RTX4090_24G};
    use crate::simulator::modelcard::LLAMA2_7B;
    use crate::simulator::replica::ServiceConfig;
    use crate::workload::arrivals::{poisson_stream, RateProfile};
    use crate::workload::corpus::{CorpusMix, ALL_FAMILIES};

    fn two_device_cluster(w: Vec<f64>) -> ClusterSim {
        let cfg = ServiceConfig {
            max_num_seqs: 48,
            gpu_memory: 0.9,
            max_tokens: 512,
            parallel_size: 1,
        };
        ClusterSim::new(
            vec![
                Replica::new(&A100_80G, &LLAMA2_7B, cfg),
                Replica::new(&RTX4090_24G, &LLAMA2_7B, cfg),
            ],
            w,
        )
    }

    #[test]
    fn weighted_routing_respects_proportions() {
        let mut rng = Pcg64::new(91);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(6.0), &mix, 300.0, &mut rng);
        let cluster = two_device_cluster(vec![3.0, 1.0]);
        let res = cluster.simulate(&arrivals, 600.0, 1);
        let n0: f64 = res.per_replica[0]
            .frames
            .iter()
            .map(|(_, f)| f.n_arriving)
            .sum();
        let n1: f64 = res.per_replica[1]
            .frames
            .iter()
            .map(|(_, f)| f.n_arriving)
            .sum();
        let ratio = n0 / n1.max(1.0);
        assert!((2.4..3.8).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bad_weights_overload_weak_device() {
        // Fig. 4 third finding: routing too much to the weak GPU explodes early
        let mut rng = Pcg64::new(92);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(14.0), &mix, 400.0, &mut rng);
        let issued = arrivals.len();
        let good = two_device_cluster(vec![1.0, 0.6]).simulate(&arrivals, 700.0, 2);
        let bad = two_device_cluster(vec![0.2, 1.8]).simulate(&arrivals, 700.0, 2);
        assert!(
            good.completion_ratio(issued) > bad.completion_ratio(issued),
            "good {} vs bad {}",
            good.completion_ratio(issued),
            bad.completion_ratio(issued)
        );
    }

    #[test]
    fn throughput_aggregates_over_gpus() {
        let mut rng = Pcg64::new(93);
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(4.0), &mix, 200.0, &mut rng);
        let res = two_device_cluster(vec![1.0, 0.8]).simulate(&arrivals, 500.0, 3);
        assert_eq!(res.total_gpus(), 2);
        assert!(res.throughput_per_gpu() > 0.0);
        assert!(res.mean_normalized_latency().is_finite());
    }
}
