//! End-to-end request tracing + decision flight recorder (§IV: ENOVA
//! "deconstructs the execution process of LLM service comprehensively").
//!
//! A trace ID is minted at ingress (coordinator or single-node gateway)
//! and propagated coordinator→node via a W3C-`traceparent`-style header
//! on the proxy hop:
//!
//! ```text
//! traceparent: 00-<32 hex trace-id>-<16 hex parent-span-id>-<2 hex flags>
//! ```
//!
//! Each service accumulates *phase spans* — admission, dispatch,
//! queue_wait, prefill (TTFT), decode, sse — plus proxy/retry spans on
//! the coordinator side. Phases are a non-overlapping partition of the
//! request's node-side timeline, so `sum(phase durations) ≈ total`; the
//! e2e test holds that to within 10%.
//!
//! Finished traces land in a sharded ring buffer with tail-based
//! retention: error (status ≥ 500) and slow-over-SLO traces are always
//! kept in a dedicated ring, the rest only when the head-based sampling
//! decision (made at mint, carried in the flags byte) said yes. Scaling
//! and placement decisions land in a separate flight-recorder ring with
//! a structured cause snapshot. Both export as JSON via `/debug/traces`
//! and `/debug/decisions`.
//!
//! std-only: randomness comes from hashing an atomic counter + the clock
//! through `RandomState` (SipHash with a per-process random key).

use crate::util::json::{num, obj, s, Json};
use std::collections::hash_map::RandomState;
use std::collections::VecDeque;
use std::hash::{BuildHasher, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Lifecycle phase names, in timeline order. `queue_wait` covers
/// enqueue→engine-submit, `prefill` covers submit→first token (TTFT),
/// `decode` first token→completion, `sse` completion→stream flushed.
pub const PHASE_ADMISSION: &str = "admission";
pub const PHASE_DISPATCH: &str = "dispatch";
pub const PHASE_QUEUE_WAIT: &str = "queue_wait";
pub const PHASE_PREFILL: &str = "prefill";
pub const PHASE_DECODE: &str = "decode";
pub const PHASE_SSE: &str = "sse";

/// Every phase a request can pass through, for metrics registration and
/// smoke-test assertions.
pub const PHASES: [&str; 6] = [
    PHASE_ADMISSION,
    PHASE_DISPATCH,
    PHASE_QUEUE_WAIT,
    PHASE_PREFILL,
    PHASE_DECODE,
    PHASE_SSE,
];

fn unix_now() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Process-local pseudo-random 64-bit value: an atomic counter + clock
/// nanos hashed through SipHash keyed with `RandomState`'s per-process
/// random seed. Never returns 0 (the W3C spec reserves all-zero IDs).
fn rand_u64() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
        .unwrap_or(0);
    let mut h = RandomState::new().build_hasher();
    h.write_u64(n);
    h.write_u64(t);
    let v = h.finish();
    if v == 0 {
        1
    } else {
        v
    }
}

/// Deterministic head-based sampling: the trace ID doubles as the coin,
/// so every service along the path agrees without extra coordination.
fn decide_sample(trace_id: u128, rate: f64) -> bool {
    if rate >= 1.0 {
        return true;
    }
    if rate <= 0.0 {
        return false;
    }
    let frac = ((trace_id as u64) >> 11) as f64 / (1u64 << 53) as f64;
    frac < rate
}

fn is_lower_hex(sx: &str) -> bool {
    sx.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
}

/// The propagated trace context: trace ID, parent span ID and the
/// sampled flag, exactly the fields a `traceparent` header carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    pub trace_id: u128,
    pub span_id: u64,
    pub sampled: bool,
}

impl TraceContext {
    /// Mint a fresh context at ingress; the sampling decision is made
    /// here and carried in the flags byte for the rest of the path.
    pub fn mint(sample_rate: f64) -> TraceContext {
        let hi = rand_u64() as u128;
        let lo = rand_u64() as u128;
        let trace_id = (hi << 64) | lo;
        TraceContext {
            trace_id,
            span_id: rand_u64(),
            sampled: decide_sample(trace_id, sample_rate),
        }
    }

    /// A child context for the next hop: same trace, fresh span ID,
    /// inherited sampling decision.
    pub fn child(&self) -> TraceContext {
        TraceContext {
            trace_id: self.trace_id,
            span_id: rand_u64(),
            sampled: self.sampled,
        }
    }

    /// Strict parse of a `00-`-version traceparent header. Rejects
    /// wrong field counts/lengths, non-lowercase-hex, unknown versions
    /// and the all-zero IDs the spec forbids.
    pub fn parse(header: &str) -> Option<TraceContext> {
        let parts: Vec<&str> = header.trim().split('-').collect();
        if parts.len() != 4 {
            return None;
        }
        let (version, trace_hex, span_hex, flags_hex) = (parts[0], parts[1], parts[2], parts[3]);
        if version != "00" || trace_hex.len() != 32 || span_hex.len() != 16 || flags_hex.len() != 2
        {
            return None;
        }
        if !is_lower_hex(trace_hex) || !is_lower_hex(span_hex) || !is_lower_hex(flags_hex) {
            return None;
        }
        let trace_id = u128::from_str_radix(trace_hex, 16).ok()?;
        let span_id = u64::from_str_radix(span_hex, 16).ok()?;
        let flags = u8::from_str_radix(flags_hex, 16).ok()?;
        if trace_id == 0 || span_id == 0 {
            return None;
        }
        Some(TraceContext {
            trace_id,
            span_id,
            sampled: flags & 0x01 == 0x01,
        })
    }

    pub fn to_traceparent(&self) -> String {
        format!(
            "00-{:032x}-{:016x}-{:02x}",
            self.trace_id,
            self.span_id,
            u8::from(self.sampled)
        )
    }

    pub fn trace_id_hex(&self) -> String {
        format!("{:032x}", self.trace_id)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// A lifecycle phase: phases partition the timeline, so their
    /// durations sum to ≈ the trace total.
    Phase,
    /// A coordinator-side proxy attempt to a node (overlaps phases).
    Proxy,
    /// A failed attempt that forced a re-dispatch.
    Retry,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Phase => "phase",
            SpanKind::Proxy => "proxy",
            SpanKind::Retry => "retry",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    pub kind: SpanKind,
    /// Offset from the trace's local start, seconds.
    pub start_s: f64,
    pub dur_s: f64,
    pub attrs: Vec<(&'static str, String)>,
}

/// A trace being built while its request is in flight. Shared across
/// the HTTP handler and the replica worker via `Arc`; the span list is
/// the only shared mutable state, behind a short-hold mutex.
pub struct ActiveTrace {
    ctx: TraceContext,
    service: String,
    endpoint: String,
    started: Instant,
    start_unix: f64,
    spans: Mutex<Vec<Span>>,
}

impl ActiveTrace {
    pub fn begin(ctx: TraceContext, service: &str, endpoint: &str) -> Arc<ActiveTrace> {
        Arc::new(ActiveTrace {
            ctx,
            service: service.to_string(),
            endpoint: endpoint.to_string(),
            started: Instant::now(),
            start_unix: unix_now(),
            spans: Mutex::new(Vec::new()),
        })
    }

    pub fn ctx(&self) -> TraceContext {
        self.ctx
    }

    pub fn started(&self) -> Instant {
        self.started
    }

    fn offset(&self, at: Instant) -> f64 {
        at.saturating_duration_since(self.started).as_secs_f64()
    }

    pub fn span(
        &self,
        name: &'static str,
        kind: SpanKind,
        from: Instant,
        to: Instant,
        attrs: Vec<(&'static str, String)>,
    ) {
        let span = Span {
            name,
            kind,
            start_s: self.offset(from),
            dur_s: to.saturating_duration_since(from).as_secs_f64(),
            attrs,
        };
        self.spans.lock().unwrap().push(span);
    }

    /// Record a lifecycle phase span over [from, to).
    pub fn phase(&self, name: &'static str, from: Instant, to: Instant) {
        self.span(name, SpanKind::Phase, from, to, Vec::new());
    }

    /// Snapshot the trace into an immutable record. Spans are sorted by
    /// start offset so exports read as a timeline.
    pub fn finish(&self, status: u16, slo: Duration) -> TraceRecord {
        let total_s = self.started.elapsed().as_secs_f64();
        let mut spans = self.spans.lock().unwrap().clone();
        spans.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));
        TraceRecord {
            trace_id: self.ctx.trace_id_hex(),
            sampled: self.ctx.sampled,
            service: self.service.clone(),
            endpoint: self.endpoint.clone(),
            status,
            start_unix: self.start_unix,
            total_s,
            error: status >= 500,
            slow: slo > Duration::ZERO && total_s > slo.as_secs_f64(),
            spans,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// 32-char lowercase hex.
    pub trace_id: String,
    pub sampled: bool,
    pub service: String,
    pub endpoint: String,
    pub status: u16,
    pub start_unix: f64,
    pub total_s: f64,
    pub error: bool,
    pub slow: bool,
    pub spans: Vec<Span>,
}

impl TraceRecord {
    /// Sum of phase-kind span durations. Phases partition the local
    /// timeline, so this tracks `total_s` closely.
    pub fn phase_total(&self) -> f64 {
        self.spans
            .iter()
            .filter(|sp| sp.kind == SpanKind::Phase)
            .map(|sp| sp.dur_s)
            .sum()
    }

    pub fn has_phase(&self, name: &str) -> bool {
        self.spans
            .iter()
            .any(|sp| sp.kind == SpanKind::Phase && sp.name == name)
    }

    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|sp| span_json(sp, &self.service))
            .collect();
        obj([
            ("trace_id", s(&self.trace_id)),
            ("service", s(&self.service)),
            ("endpoint", s(&self.endpoint)),
            ("status", num(f64::from(self.status))),
            ("start_unix", num(self.start_unix)),
            ("total_seconds", num(self.total_s)),
            ("phase_seconds_total", num(self.phase_total())),
            ("error", Json::Bool(self.error)),
            ("slow", Json::Bool(self.slow)),
            ("sampled", Json::Bool(self.sampled)),
            ("spans", Json::Arr(spans)),
        ])
    }
}

fn span_json(sp: &Span, service: &str) -> Json {
    let mut fields = vec![
        ("name", s(sp.name)),
        ("kind", s(sp.kind.name())),
        ("service", s(service)),
        ("start_seconds", num(sp.start_s)),
        ("duration_seconds", num(sp.dur_s)),
    ];
    if !sp.attrs.is_empty() {
        fields.push((
            "attrs",
            Json::Obj(
                sp.attrs
                    .iter()
                    .map(|(k, v)| ((*k).to_string(), Json::Str(v.clone())))
                    .collect(),
            ),
        ));
    }
    obj(fields)
}

/// Trace subsystem knobs, shared by gateway and coordinator configs.
#[derive(Debug, Clone)]
pub struct TraceSettings {
    /// Head-based sampling rate in [0, 1] for normal traces; error and
    /// slow traces are always retained regardless.
    pub sample_rate: f64,
    /// A trace slower than this is "slow" and always retained. Zero
    /// disables the slow classification.
    pub slo: Duration,
    /// Total ring capacity (split across shards, kept and sampled rings
    /// each get the per-shard share).
    pub capacity: usize,
}

impl Default for TraceSettings {
    fn default() -> TraceSettings {
        TraceSettings {
            sample_rate: 1.0,
            slo: Duration::from_secs(2),
            capacity: 512,
        }
    }
}

const TRACE_SHARDS: usize = 8;

#[derive(Default)]
struct Shard {
    /// error/slow traces — never evicted by normal traffic.
    kept: VecDeque<TraceRecord>,
    /// head-sampled normal traces.
    sampled: VecDeque<TraceRecord>,
}

/// Lock-light finished-trace store: 8 shards keyed by trace ID so
/// concurrent HTTP workers rarely contend, two rings per shard for
/// tail-based retention.
pub struct TraceRecorder {
    settings: TraceSettings,
    shards: Vec<Mutex<Shard>>,
    recorded: AtomicU64,
    dropped: AtomicU64,
}

impl TraceRecorder {
    pub fn new(settings: TraceSettings) -> TraceRecorder {
        TraceRecorder {
            settings,
            shards: (0..TRACE_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn settings(&self) -> &TraceSettings {
        &self.settings
    }

    fn shard_cap(&self) -> usize {
        (self.settings.capacity / TRACE_SHARDS).max(1)
    }

    fn shard_index(trace_id: &str) -> usize {
        let h = trace_id
            .as_bytes()
            .iter()
            .fold(0usize, |acc, &b| acc.wrapping_mul(31).wrapping_add(b as usize));
        h % TRACE_SHARDS
    }

    /// Tail-based retention: error/slow records always land in the kept
    /// ring; everything else is admitted only if head-sampled.
    pub fn record(&self, rec: TraceRecord) {
        let important = rec.error || rec.slow;
        if !important && !rec.sampled {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let cap = self.shard_cap();
        let mut shard = self.shards[Self::shard_index(&rec.trace_id)].lock().unwrap();
        let ring = if important {
            &mut shard.kept
        } else {
            &mut shard.sampled
        };
        ring.push_back(rec);
        while ring.len() > cap {
            ring.pop_front();
        }
        drop(shard);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// All retained records, oldest first.
    pub fn traces(&self) -> Vec<TraceRecord> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            out.extend(shard.kept.iter().cloned());
            out.extend(shard.sampled.iter().cloned());
        }
        out.sort_by(|a, b| a.start_unix.total_cmp(&b.start_unix));
        out
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|sh| {
                let sh = sh.lock().unwrap();
                sh.kept.len() + sh.sampled.len()
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The `/debug/traces` payload.
    pub fn export_json(&self) -> Json {
        let traces: Vec<Json> = self.traces().iter().map(TraceRecord::to_json).collect();
        obj([
            ("recorded", num(self.recorded() as f64)),
            ("dropped_unsampled", num(self.dropped() as f64)),
            ("sample_rate", num(self.settings.sample_rate)),
            ("slo_seconds", num(self.settings.slo.as_secs_f64())),
            ("capacity", num(self.settings.capacity as f64)),
            ("traces", Json::Arr(traces)),
        ])
    }
}

/// One autoscaling/placement decision with its cause snapshot.
#[derive(Debug, Clone)]
pub struct Decision {
    pub at_unix: f64,
    pub service: String,
    /// What happened: scale_up | scale_down | reconfigure | placement |
    /// retirement | node_scale_up | node_scale_down.
    pub kind: String,
    /// Why: detector | queue_wait | forecast | backfill | recommender |
    /// coordinator | admin.
    pub reason: String,
    /// Structured cause snapshot: detector score, forecast rps + WMAPE,
    /// queue-wait quantile, chosen node, bin-packing inputs, …
    pub attrs: Vec<(&'static str, String)>,
}

impl Decision {
    pub fn to_json(&self) -> Json {
        obj([
            ("at_unix", num(self.at_unix)),
            ("service", s(&self.service)),
            ("kind", s(&self.kind)),
            ("reason", s(&self.reason)),
            (
                "attrs",
                Json::Obj(
                    self.attrs
                        .iter()
                        .map(|(k, v)| ((*k).to_string(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
        ])
    }
}

/// The decision flight recorder: a bounded ring of every scale,
/// reconfigure, placement and backfill decision the control plane made,
/// each with the inputs that caused it. `/debug/decisions` serves it.
pub struct DecisionRecorder {
    capacity: usize,
    ring: Mutex<VecDeque<Decision>>,
    recorded: AtomicU64,
}

impl DecisionRecorder {
    pub fn new(capacity: usize) -> DecisionRecorder {
        DecisionRecorder {
            capacity: capacity.max(1),
            ring: Mutex::new(VecDeque::new()),
            recorded: AtomicU64::new(0),
        }
    }

    pub fn record(
        &self,
        service: &str,
        kind: &str,
        reason: &str,
        attrs: Vec<(&'static str, String)>,
    ) {
        let decision = Decision {
            at_unix: unix_now(),
            service: service.to_string(),
            kind: kind.to_string(),
            reason: reason.to_string(),
            attrs,
        };
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(decision);
        while ring.len() > self.capacity {
            ring.pop_front();
        }
        drop(ring);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn decisions(&self) -> Vec<Decision> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// The `/debug/decisions` payload.
    pub fn export_json(&self) -> Json {
        let decisions: Vec<Json> = self.decisions().iter().map(Decision::to_json).collect();
        obj([
            ("recorded", num(self.recorded() as f64)),
            ("capacity", num(self.capacity as f64)),
            ("decisions", Json::Arr(decisions)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traceparent_round_trips() {
        let ctx = TraceContext::mint(1.0);
        assert!(ctx.sampled);
        let header = ctx.to_traceparent();
        assert_eq!(header.len(), 55);
        let back = TraceContext::parse(&header).expect("own header parses");
        assert_eq!(back, ctx);

        let unsampled = TraceContext {
            trace_id: 0xabcdef,
            span_id: 0x1234,
            sampled: false,
        };
        let back = TraceContext::parse(&unsampled.to_traceparent()).unwrap();
        assert_eq!(back, unsampled);
        assert!(!back.sampled);
    }

    #[test]
    fn traceparent_rejects_malformed() {
        let good = "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01";
        assert!(TraceContext::parse(good).is_some());
        let bad = [
            "",
            "garbage",
            // wrong version
            "01-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01",
            // uppercase hex
            "00-0AF7651916CD43DD8448EB211C80319C-b7ad6b7169203331-01",
            // short trace id
            "00-0af7651916cd43dd8448eb211c8031-b7ad6b7169203331-01",
            // short span id
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b71692033-01",
            // non-hex
            "00-0af7651916cd43dd8448eb211c80319z-b7ad6b7169203331-01",
            // all-zero ids are forbidden
            "00-00000000000000000000000000000000-b7ad6b7169203331-01",
            "00-0af7651916cd43dd8448eb211c80319c-0000000000000000-01",
            // missing / extra fields
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331",
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01-extra",
        ];
        for case in bad {
            assert!(TraceContext::parse(case).is_none(), "accepted: {case:?}");
        }
    }

    #[test]
    fn child_keeps_trace_id_and_sampling() {
        let parent = TraceContext::mint(1.0);
        let child = parent.child();
        assert_eq!(child.trace_id, parent.trace_id);
        assert_ne!(child.span_id, parent.span_id);
        assert_eq!(child.sampled, parent.sampled);
    }

    #[test]
    fn sampling_is_deterministic_per_trace() {
        let ctx = TraceContext::mint(0.5);
        // re-deciding with the same id gives the same answer everywhere
        assert_eq!(decide_sample(ctx.trace_id, 0.5), ctx.sampled);
        assert!(decide_sample(ctx.trace_id, 1.0));
        assert!(!decide_sample(ctx.trace_id, 0.0));
    }

    #[test]
    fn spans_export_sorted_and_phases_partition_the_timeline() {
        let trace = ActiveTrace::begin(TraceContext::mint(1.0), "gateway", "/v1/completions");
        let t0 = trace.started();
        let t1 = t0 + Duration::from_millis(10);
        let t2 = t0 + Duration::from_millis(30);
        let t3 = t0 + Duration::from_millis(70);
        // record out of order on purpose
        trace.phase(PHASE_QUEUE_WAIT, t1, t2);
        trace.phase(PHASE_ADMISSION, t0, t1);
        trace.span(
            "attempt",
            SpanKind::Retry,
            t0,
            t1,
            vec![("cause", "node_death".to_string())],
        );
        trace.phase(PHASE_DECODE, t2, t3);
        std::thread::sleep(Duration::from_millis(1));
        let rec = trace.finish(200, Duration::from_secs(2));

        let names: Vec<&str> = rec.spans.iter().map(|sp| sp.name).collect();
        // sorted by start offset; the retry span shares t0 with admission
        assert_eq!(names.len(), 4);
        assert_eq!(names[2], PHASE_QUEUE_WAIT);
        assert_eq!(names[3], PHASE_DECODE);
        let starts: Vec<f64> = rec.spans.iter().map(|sp| sp.start_s).collect();
        assert!(starts.windows(2).all(|w| w[0] <= w[1]), "sorted: {starts:?}");

        // phases sum to 70ms exactly; the retry span is excluded
        assert!((rec.phase_total() - 0.070).abs() < 1e-9, "{}", rec.phase_total());
        assert!(rec.has_phase(PHASE_ADMISSION));
        assert!(!rec.has_phase("attempt"));
        assert!(!rec.error && !rec.slow);

        // JSON carries the retry attrs
        let j = rec.to_json();
        let spans = j.get("spans").and_then(Json::as_arr).unwrap();
        let retry = spans
            .iter()
            .find(|sp| sp.get("kind").and_then(Json::as_str) == Some("retry"))
            .unwrap();
        assert_eq!(
            retry.get("attrs").and_then(|a| a.get("cause")).and_then(Json::as_str),
            Some("node_death")
        );
    }

    fn rec(id: u64, status: u16, sampled: bool, slow: bool) -> TraceRecord {
        TraceRecord {
            trace_id: format!("{:032x}", id as u128),
            sampled,
            service: "gateway".into(),
            endpoint: "/v1/completions".into(),
            status,
            start_unix: id as f64,
            total_s: if slow { 9.0 } else { 0.01 },
            error: status >= 500,
            slow,
            spans: Vec::new(),
        }
    }

    #[test]
    fn ring_retention_under_overflow_keeps_errors_and_slow() {
        let recorder = TraceRecorder::new(TraceSettings {
            sample_rate: 1.0,
            slo: Duration::from_secs(2),
            capacity: 16,
        });
        // two important records early on
        recorder.record(rec(1, 503, true, false));
        recorder.record(rec(2, 200, true, true));
        // then a flood of normal traffic far past capacity
        for i in 10..500 {
            recorder.record(rec(i, 200, true, false));
        }
        assert!(recorder.len() <= 16 + 2 * 8, "bounded: {}", recorder.len());
        let traces = recorder.traces();
        assert!(
            traces.iter().any(|t| t.trace_id.ends_with('1') && t.error),
            "error trace survived the flood"
        );
        assert!(traces.iter().any(|t| t.slow), "slow trace survived the flood");
        // newest normal traffic is present, oldest evicted
        assert!(traces.iter().any(|t| t.start_unix > 490.0));
        assert!(!traces
            .iter()
            .any(|t| (10.0..20.0).contains(&t.start_unix) && !t.error && !t.slow));
    }

    #[test]
    fn unsampled_normal_traces_drop_but_unsampled_errors_keep() {
        let recorder = TraceRecorder::new(TraceSettings {
            sample_rate: 0.0,
            slo: Duration::from_secs(2),
            capacity: 16,
        });
        recorder.record(rec(1, 200, false, false));
        assert_eq!(recorder.len(), 0);
        assert_eq!(recorder.dropped(), 1);
        // tail-based: errors survive even when head-sampling said no
        recorder.record(rec(2, 500, false, false));
        recorder.record(rec(3, 200, false, true));
        assert_eq!(recorder.len(), 2);
        assert_eq!(recorder.recorded(), 2);
    }

    #[test]
    fn decision_ring_caps_and_exports() {
        let recorder = DecisionRecorder::new(4);
        for i in 0..10 {
            recorder.record(
                "coordinator",
                "placement",
                if i % 2 == 0 { "forecast" } else { "backfill" },
                vec![("node", format!("node-{i}"))],
            );
        }
        assert_eq!(recorder.len(), 4);
        assert_eq!(recorder.recorded(), 10);
        let j = recorder.export_json();
        let ds = j.get("decisions").and_then(Json::as_arr).unwrap();
        assert_eq!(ds.len(), 4);
        // oldest evicted: the ring starts at i=6
        assert_eq!(
            ds[0].get("attrs").and_then(|a| a.get("node")).and_then(Json::as_str),
            Some("node-6")
        );
        assert_eq!(j.get("recorded").and_then(Json::as_f64), Some(10.0));
    }
}
