//! # ENOVA — autoscaling towards cost-effective and stable serverless LLM serving
//!
//! Rust + JAX + Pallas reproduction of Huang et al. (CS.DC 2024). The crate
//! is the L3 coordinator of the three-layer architecture (DESIGN.md):
//!
//! * [`runtime`] loads the AOT-compiled HLO artifacts (tiny LLaMA-style LM,
//!   detection VAE, request embedder) onto a PJRT CPU client.
//! * [`engine`] is an in-tree continuous-batching inference engine over
//!   those executables; [`router`] load-balances replicas with the weighted
//!   routing of §IV-A-4.
//! * [`gateway`] is the network-facing serving surface: an OpenAI-compatible
//!   HTTP server with SSE streaming, admission control and a Prometheus
//!   `/metrics` endpoint, dispatching through the router to engine replicas.
//! * [`cluster`] is the distributed serving plane (§V's deployment
//!   execution engine): a coordinator that owns ingress, routes across
//!   `enova node` processes, and turns scaling decisions into cross-node
//!   *placements* (bin-packing by free `gpu_memory`, spread-by-default).
//! * [`config`] is the paper's service configuration module (OLS + t-test,
//!   KDE, EVT, task clustering, linear programming).
//! * [`detect`] is the performance detection module (semi-supervised VAE +
//!   POT threshold + MD up/down rule) plus the Table IV baselines.
//! * [`autoscaler`] closes the loop: monitor → detect → reconfigure →
//!   redeploy, against either the real engine or the calibrated multi-GPU
//!   [`simulator`].
//!
//! Everything below `util`/`stats`/`nn` is substrate we had to build because
//! the offline environment only ships the `xla` + `anyhow` crates.

pub mod util {
    pub mod cli;
    pub mod exec;
    pub mod json;
    pub mod log;
    pub mod prop;
    pub mod rng;
}

pub mod nn {
    pub mod autograd;
    pub mod layers;
    pub mod optim;
    pub mod tensor;
}

pub mod stats {
    pub mod descriptive;
    pub mod evt;
    pub mod kde;
    pub mod lp;
    pub mod ols;
    pub mod pca;
    pub mod tdist;
}

pub mod autoscaler;
pub mod baselines;
pub mod bench;
pub mod chaos;
pub mod cluster;
pub mod clusterer;
pub mod config;
pub mod deployer;
pub mod detect;
pub mod engine;
pub mod forecast;
pub mod gateway;
pub mod metrics;
pub mod router;
#[cfg(feature = "xla-runtime")]
pub mod runtime;
pub mod settings;
pub mod trace;
pub mod tsdb;

pub mod simulator {
    pub mod cluster;
    pub mod gpu;
    pub mod modelcard;
    pub mod replica;
}

pub mod workload {
    pub mod arrivals;
    pub mod corpus;
}

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
