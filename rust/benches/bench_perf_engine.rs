//! §Perf — the real serving hot path on CPU PJRT: decode-step latency and
//! end-to-end engine throughput, comparing the device-resident
//! buffer-chained mode against the naive host-roundtrip mode. This is the
//! before/after artifact of EXPERIMENTS.md §Perf.

use enova::bench::{fmt_duration, time_it, Table};
use enova::engine::{Engine, EngineConfig};
use enova::runtime::lm::{ExecMode, LmRuntime};
use enova::runtime::{Manifest, PjRt};

fn main() {
    let manifest = Manifest::load(&Manifest::default_dir()).expect("run `make artifacts`");
    let rt = PjRt::cpu().expect("pjrt");

    let mut table = Table::new(
        "§Perf — LM runtime hot path (tiny-lm, CPU PJRT)",
        &["mode", "op", "batch_active", "p50", "p99", "tok_per_s"],
    );

    for mode in [ExecMode::HostRoundtrip, ExecMode::Chained] {
        let mode_name = match mode {
            ExecMode::Chained => "chained",
            ExecMode::HostRoundtrip => "host-roundtrip",
        };
        let mut lm = LmRuntime::load(rt.clone(), &manifest, mode).expect("lm");
        let b = lm.spec.batch;

        // fill all slots
        for slot in 0..b {
            let prompt: Vec<i32> = (3..35).map(|x| (x % 500) + 3).collect();
            lm.prefill(&prompt, slot).expect("prefill");
        }
        let tokens = vec![7i32; b];
        let mut lens: Vec<i32> = vec![40; b];

        // decode-step latency at full batch
        let t = time_it(5, 40, || {
            lm.decode(&tokens, &lens).expect("decode");
            let _ = lm.all_logits().expect("logits");
            for l in lens.iter_mut() {
                *l = (*l + 1).min((lm.spec.max_seq - 2) as i32);
            }
        });
        table.row(&[
            mode_name.into(),
            "decode+logits".into(),
            b.to_string(),
            fmt_duration(t.p50()),
            fmt_duration(t.p99()),
            format!("{:.0}", b as f64 / t.p50()),
        ]);

        // prefill latency
        let mut lm2 = LmRuntime::load(rt.clone(), &manifest, mode).expect("lm");
        let prompt: Vec<i32> = (3..99).map(|x| (x % 500) + 3).collect();
        let mut slot = 0usize;
        let t = time_it(2, 20, || {
            lm2.prefill(&prompt, slot % b).expect("prefill");
            slot += 1;
        });
        table.row(&[
            mode_name.into(),
            "prefill(96tok)".into(),
            "1".into(),
            fmt_duration(t.p50()),
            fmt_duration(t.p99()),
            format!("{:.0}", 96.0 / t.p50()),
        ]);
    }

    // end-to-end engine throughput (chained mode)
    let lm = LmRuntime::load(rt, &manifest, ExecMode::Chained).expect("lm");
    let mut engine = Engine::new(
        lm,
        EngineConfig {
            max_num_seqs: 8,
            max_tokens: 24,
            temperature: 0.0,
        },
        3,
    );
    for i in 0..32 {
        engine.submit(&format!("request number {i}: compute something"), 24);
    }
    let t0 = std::time::Instant::now();
    let completions = engine.run_to_completion().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = completions.iter().map(|c| c.tokens.len()).sum();
    table.row(&[
        "chained".into(),
        "engine e2e (32 reqs)".into(),
        "8".into(),
        fmt_duration(wall),
        "-".into(),
        format!("{:.0}", tokens as f64 / wall),
    ]);

    table.print();
    table.dump_csv("perf_engine");

    // chained must beat host-roundtrip on the decode path
    let chained_p50: f64 = {
        let row = table
            .rows
            .iter()
            .find(|r| r[0] == "chained" && r[1] == "decode+logits")
            .unwrap();
        row[5].parse::<f64>().unwrap()
    };
    let host_p50: f64 = {
        let row = table
            .rows
            .iter()
            .find(|r| r[0] == "host-roundtrip" && r[1] == "decode+logits")
            .unwrap();
        row[5].parse::<f64>().unwrap()
    };
    println!(
        "decode tok/s: chained {chained_p50:.0} vs host-roundtrip {host_p50:.0} ({:.2}x)",
        chained_p50 / host_p50
    );
    assert!(completions.len() == 32);
    println!("OK: perf harness complete");
}
