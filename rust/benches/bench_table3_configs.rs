//! Table III — recommended configurations of Default / COSE / DDPG / ENOVA
//! for L-7B and L-70B on A100-80G and RTX4090-24G, including the
//! per-task max_tokens (gsm8k / mbpp) and routing weights.

use enova::bench::scenarios;
use enova::bench::Table;
use enova::simulator::gpu::{A100_80G, RTX4090_24G};
use enova::simulator::modelcard::{LLAMA2_70B, LLAMA2_7B};

fn main() {
    let (gsm_mt, mbpp_mt) = scenarios::enova_max_tokens_per_task(11);
    println!("ENOVA per-community max_tokens: gsm8k={gsm_mt} mbpp={mbpp_mt} (paper: 414 / 956)");

    let mut table = Table::new(
        "Table III — recommended configurations",
        &["method", "LLM", "device", "max_num_seqs", "max_tokens(gsm8k/mbpp)", "gpu_mem", "tp", "weight"],
    );

    for model in [&LLAMA2_7B, &LLAMA2_70B] {
        // per-device method configs
        let a100 = scenarios::all_method_configs(&A100_80G, model, 21);
        let r4090 = scenarios::all_method_configs(&RTX4090_24G, model, 22);
        for (ma, mr) in a100.iter().zip(&r4090) {
            assert_eq!(ma.method, mr.method);
            let wmax = ma.weight_basis.max(mr.weight_basis).max(1e-9);
            for (dev, m, basis) in [
                ("A100", ma, ma.weight_basis),
                ("4090", mr, mr.weight_basis),
            ] {
                let tokens = if m.method == "ENOVA" {
                    format!("{gsm_mt}/{mbpp_mt}")
                } else if m.method == "Default" {
                    "256/256".to_string()
                } else {
                    format!("{}/{}", m.config.max_tokens, m.config.max_tokens)
                };
                table.row(&[
                    m.method.to_string(),
                    model.name.to_string(),
                    dev.to_string(),
                    m.config.max_num_seqs.to_string(),
                    tokens,
                    format!("{:.2}", m.config.gpu_memory),
                    m.config.parallel_size.to_string(),
                    format!("{:.2}", basis / wmax),
                ]);
            }
        }
    }
    table.print();
    table.dump_csv("table3_configs");

    // Shape assertions mirroring the paper's reading of Table III:
    let get = |method: &str, model: &str, dev: &str| -> usize {
        table
            .rows
            .iter()
            .find(|r| r[0] == method && r[1] == model && r[2] == dev)
            .map(|r| r[3].parse().unwrap())
            .unwrap()
    };
    // 1. throughput-maximizing baselines over-provision max_num_seqs vs
    //    ENOVA (DDPG is a noisy learner, so compare the baseline average)
    assert!(get("COSE", "L-7B", "A100") > get("ENOVA", "L-7B", "A100"));
    let baseline_avg = (get("COSE", "L-7B", "A100") + get("DDPG", "L-7B", "A100")) as f64 / 2.0;
    assert!(baseline_avg > get("ENOVA", "L-7B", "A100") as f64);
    // 2. everyone recommends far less concurrency for 70B than 7B
    assert!(get("ENOVA", "L-70B", "A100") < get("ENOVA", "L-7B", "A100"));
    // 3. the 4090 gets a lower weight than the A100 under ENOVA
    let w4090: f64 = table
        .rows
        .iter()
        .find(|r| r[0] == "ENOVA" && r[1] == "L-7B" && r[2] == "4090")
        .map(|r| r[7].parse().unwrap())
        .unwrap();
    assert!(w4090 < 1.0, "4090 weight {w4090}");
    println!("OK: Table III shape reproduced (baselines over-provision; 70B ≪ 7B; 4090 down-weighted)");
}
