//! Fig. 1 — running/pending requests at rps just below vs just above the
//! service limit. Reproduces the paper's motivating observation: at rps 6
//! all requests drain; at rps 7 the pending queue grows without bound once
//! running hits max_num_seqs.

use enova::bench::{render_series, Table};
use enova::simulator::gpu::A100_80G;
use enova::simulator::modelcard::LLAMA2_7B;
use enova::simulator::replica::{Replica, ServiceConfig};
use enova::util::rng::Pcg64;
use enova::workload::arrivals::{poisson_stream, RateProfile};
use enova::workload::corpus::{CorpusMix, ALL_FAMILIES};

fn main() {
    let cfg = ServiceConfig {
        max_num_seqs: 32,
        gpu_memory: 0.9,
        max_tokens: 512,
        parallel_size: 1,
    };
    // Locate the capacity cliff for this (model, GPU, config), then probe
    // one rps below and one above — the paper's 6-vs-7 experiment.
    let rep = Replica::new(&A100_80G, &LLAMA2_7B, cfg);
    let mix = CorpusMix::uniform(&ALL_FAMILIES);
    let horizon = 900.0; // the paper uses 15-minute traces

    // cliff = first rps where the replica stops draining its queue
    // (completion < 90% of issued within the horizon), seed held fixed
    let mut cliff = 20.0;
    for rps2 in 2..60 {
        let rps = rps2 as f64 / 2.0;
        let mut rng = Pcg64::new(7);
        let arrivals = poisson_stream(&RateProfile::constant(rps), &mix, horizon, &mut rng);
        let issued = arrivals.len();
        let res = rep.simulate(arrivals, horizon);
        if (res.finished.len() as f64) < 0.9 * issued as f64 {
            cliff = rps;
            break;
        }
    }
    let below = (cliff - 1.5).max(0.5);
    let above = cliff + 1.0;
    println!("capacity cliff located at ~{cliff:.1} rps (paper's case: 7)");

    let mut table = Table::new(
        "Fig.1 — queue behaviour below vs above the rps limit",
        &["rps", "finished", "timed_out", "mean_pending_tail", "max_running"],
    );
    for (tag, rps) in [("below", below), ("above", above)] {
        let mut rng = Pcg64::new(7);
        let arrivals = poisson_stream(&RateProfile::constant(rps), &mix, horizon, &mut rng);
        let res = rep.simulate(arrivals, horizon);
        let times: Vec<f64> = res.frames.iter().map(|(t, _)| *t).collect();
        let running: Vec<f64> = res.frames.iter().map(|(_, f)| f.n_running).collect();
        let pending: Vec<f64> = res.frames.iter().map(|(_, f)| f.n_pending).collect();
        println!(
            "{}",
            render_series(
                &format!("running requests @ {rps:.1} rps ({tag})"),
                &times,
                &running,
                "running"
            )
        );
        println!(
            "{}",
            render_series(
                &format!("pending requests @ {rps:.1} rps ({tag})"),
                &times,
                &pending,
                "pending"
            )
        );
        let tail = pending.iter().rev().take(60).sum::<f64>() / 60.0;
        table.row(&[
            format!("{rps:.1}"),
            res.finished.len().to_string(),
            res.timed_out.to_string(),
            format!("{tail:.1}"),
            format!("{:.0}", running.iter().copied().fold(0.0, f64::max)),
        ]);
    }
    table.print();
    table.dump_csv("fig1_stability");

    // the paper's qualitative claim, asserted
    let below_tail: f64 = table.rows[0][3].parse().unwrap();
    let above_tail: f64 = table.rows[1][3].parse().unwrap();
    assert!(
        above_tail > 10.0 * below_tail.max(0.1),
        "expected queue explosion above the limit"
    );
    println!("OK: pending queue explodes just past the rps limit");
}
