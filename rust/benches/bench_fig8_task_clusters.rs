//! Fig. 8 — PCA of request embeddings across four task families
//! (gsm8k / mbpp / arc / mc_test × zero-shot / few-shot / CoT prompts):
//! same-task requests cluster; different tasks separate. Embeddings run
//! through the compiled `embed.hlo.txt` artifact; PCA is in-tree power
//! iteration.

use enova::bench::Table;
use enova::clusterer::{louvain, modularity, RequestGraph};
use enova::runtime::embedder::EmbedRuntime;
use enova::runtime::{Manifest, PjRt};
use enova::stats::pca::Pca;
use enova::util::rng::Pcg64;
use enova::workload::corpus::{render_prompt, ALL_FAMILIES, ALL_PARADIGMS};

fn main() {
    let manifest = Manifest::load(&Manifest::default_dir()).expect("run `make artifacts`");
    let rt = PjRt::cpu().expect("pjrt");
    let embedder = EmbedRuntime::load(rt, &manifest).expect("embed artifact");

    let mut rng = Pcg64::new(81);
    let per_cell = 12;
    let mut texts = Vec::new();
    let mut labels = Vec::new();
    for (fi, family) in ALL_FAMILIES.iter().enumerate() {
        for paradigm in ALL_PARADIGMS {
            for _ in 0..per_cell {
                texts.push(render_prompt(*family, paradigm, &mut rng));
                labels.push(fi);
            }
        }
    }
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let emb = embedder.embed(&refs).expect("embedding");

    // 2-D PCA projection (the figure itself)
    let pca = Pca::fit(&emb, 2).expect("pca");
    let proj: Vec<Vec<f64>> = emb.iter().map(|e| pca.transform(e)).collect();

    let mut table = Table::new(
        "Fig.8 — task-family centroids in PCA space",
        &["family", "n", "pc1", "pc2", "intra_cos", "inter_cos"],
    );
    // separation statistics
    let mut all_intra = Vec::new();
    let mut all_inter = Vec::new();
    for (fi, family) in ALL_FAMILIES.iter().enumerate() {
        let idx: Vec<usize> = (0..labels.len()).filter(|&i| labels[i] == fi).collect();
        let centroid: Vec<f64> = (0..2)
            .map(|d| idx.iter().map(|&i| proj[i][d]).sum::<f64>() / idx.len() as f64)
            .collect();
        let mut intra = Vec::new();
        let mut inter = Vec::new();
        for i in 0..labels.len() {
            for j in i + 1..labels.len() {
                let cs = enova::clusterer::cosine(&emb[i], &emb[j]);
                if labels[i] == fi || labels[j] == fi {
                    if labels[i] == labels[j] {
                        intra.push(cs);
                    } else {
                        inter.push(cs);
                    }
                }
            }
        }
        let m = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        all_intra.push(m(&intra));
        all_inter.push(m(&inter));
        table.row(&[
            family.name().to_string(),
            idx.len().to_string(),
            format!("{:.3}", centroid[0]),
            format!("{:.3}", centroid[1]),
            format!("{:.3}", m(&intra)),
            format!("{:.3}", m(&inter)),
        ]);
    }
    table.print();
    table.dump_csv("fig8_task_clusters");

    // scatter CSV for external plotting
    {
        let mut csv = String::from("family,pc1,pc2\n");
        for (i, p) in proj.iter().enumerate() {
            csv.push_str(&format!(
                "{},{:.5},{:.5}\n",
                ALL_FAMILIES[labels[i]].name(),
                p[0],
                p[1]
            ));
        }
        let _ = std::fs::create_dir_all("target/bench_out");
        let _ = std::fs::write("target/bench_out/fig8_scatter.csv", csv);
    }

    // community detection should rediscover the four families
    let graph = RequestGraph::build(&emb, 0.55);
    let assign = louvain(&graph);
    let q = modularity(&graph, &assign);
    let n_comms = assign.iter().copied().max().unwrap_or(0) + 1;
    println!("louvain: {n_comms} communities, modularity {q:.3}");

    for (i, (intra, inter)) in all_intra.iter().zip(&all_inter).enumerate() {
        assert!(
            intra > &(inter + 0.1),
            "family {} not separated: intra {intra:.3} vs inter {inter:.3}",
            ALL_FAMILIES[i].name()
        );
    }
    assert!(q > 0.3, "weak modularity {q}");
    println!("OK: same-task requests cluster, tasks separate (Fig.8 finding)");
}
