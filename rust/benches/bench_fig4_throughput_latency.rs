//! Fig. 4 — throughput (tokens/GPU/s) and normalized latency (s/token) vs
//! tps for five LLMs under Default / COSE / DDPG / ENOVA configurations on
//! the A100+4090 two-replica cluster.
//!
//! Shape targets (paper): throughput saturates with tps and is roughly
//! method-equal at saturation; latency explodes earlier for Default (≈½
//! the tps ENOVA sustains) and for COSE/DDPG (≈1/1.3×).

use enova::bench::{render_series, scenarios, Table};
use enova::simulator::gpu::{A100_80G, RTX4090_24G};
use enova::simulator::modelcard::FIG4_MODELS;

fn main() {
    let tps_sweep = [2.0, 4.0, 6.0, 9.0, 13.0, 18.0, 24.0];
    let mut table = Table::new(
        "Fig.4 — throughput & latency vs tps (A100 + 4090 cluster)",
        &["model", "method", "tps", "tok_per_gpu_s", "norm_latency_s", "completion"],
    );
    let mut sustained: std::collections::BTreeMap<(String, String), f64> = Default::default();

    for model in FIG4_MODELS {
        let a100 = scenarios::all_method_configs(&A100_80G, model, 31);
        let r4090 = scenarios::all_method_configs(&RTX4090_24G, model, 32);
        for (ma, mr) in a100.iter().zip(&r4090) {
            let cluster = scenarios::two_device_cluster(
                model,
                ma.config,
                ma.weight_basis,
                mr.config,
                mr.weight_basis,
            );
            let mut tputs = Vec::new();
            let mut lats = Vec::new();
            for (k, &tps) in tps_sweep.iter().enumerate() {
                let arrivals = scenarios::eval_trace(tps, 40 + k as u64);
                let issued = arrivals.len();
                let res = cluster.simulate(&arrivals, 1200.0, 41);
                let completion = res.completion_ratio(issued);
                let lat = res.mean_normalized_latency();
                let tput = res.throughput_per_gpu();
                table.row(&[
                    model.name.to_string(),
                    ma.method.to_string(),
                    format!("{tps:.0}"),
                    format!("{tput:.0}"),
                    if lat.is_finite() { format!("{lat:.3}") } else { "inf".into() },
                    format!("{completion:.2}"),
                ]);
                tputs.push(tput);
                lats.push(if lat.is_finite() { lat } else { 10.0 });
                // "sustained tps" = highest tps with ≥95% completion and
                // sane latency (the pre-explosion regime)
                if completion >= 0.95 && lat < 0.5 {
                    let key = (model.name.to_string(), ma.method.to_string());
                    let e = sustained.entry(key).or_insert(0.0);
                    *e = e.max(tps);
                }
            }
            if ma.method == "ENOVA" {
                println!(
                    "{}",
                    render_series(
                        &format!("{} ENOVA throughput vs tps", model.name),
                        &tps_sweep,
                        &tputs,
                        "tok/gpu/s"
                    )
                );
            }
        }
    }
    table.print();
    table.dump_csv("fig4_throughput_latency");

    let mut sus_table = Table::new(
        "Fig.4 summary — max sustained tps before latency explosion",
        &["model", "Default", "COSE", "DDPG", "ENOVA", "ENOVA/Default", "ENOVA/best-baseline"],
    );
    let mut ratios_default = Vec::new();
    let mut ratios_base = Vec::new();
    for model in FIG4_MODELS {
        let get = |m: &str| {
            sustained
                .get(&(model.name.to_string(), m.to_string()))
                .copied()
                .unwrap_or(0.0)
        };
        let (d, c, g, e) = (get("Default"), get("COSE"), get("DDPG"), get("ENOVA"));
        let rd = e / d.max(0.5);
        let rb = e / c.max(g).max(0.5);
        ratios_default.push(rd);
        ratios_base.push(rb);
        sus_table.row(&[
            model.name.to_string(),
            format!("{d:.0}"),
            format!("{c:.0}"),
            format!("{g:.0}"),
            format!("{e:.0}"),
            format!("{rd:.2}"),
            format!("{rb:.2}"),
        ]);
    }
    sus_table.print();
    sus_table.dump_csv("fig4_sustained_tps");

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "mean ENOVA/Default sustained-tps ratio: {:.2} (paper: ~2x)",
        mean(&ratios_default)
    );
    println!(
        "mean ENOVA/best-baseline ratio: {:.2} (paper: ~1.3x)",
        mean(&ratios_base)
    );
    assert!(
        mean(&ratios_default) >= 1.3,
        "ENOVA should clearly out-sustain Default"
    );
    assert!(
        mean(&ratios_base) >= 0.95,
        "ENOVA should match or beat the tuned baselines"
    );
    println!("OK: Fig.4 shape reproduced");
}
