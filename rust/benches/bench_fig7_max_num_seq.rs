//! Fig. 7 — maximal finished requests/s and KV memory utilization as
//! max_num_seqs grows: throughput plateaus at the compute knee while
//! memory keeps rising (diminishing returns, §VII-A).

use enova::bench::{render_series, Table};
use enova::simulator::gpu::A100_80G;
use enova::simulator::modelcard::LLAMA2_7B;
use enova::simulator::replica::{Replica, ServiceConfig};
use enova::util::rng::Pcg64;
use enova::workload::arrivals::{poisson_stream, RateProfile};
use enova::workload::corpus::{CorpusMix, ALL_FAMILIES};

fn main() {
    let mix = CorpusMix::uniform(&ALL_FAMILIES);
    let horizon = 600.0;
    let sweep = [4usize, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512];

    let mut table = Table::new(
        "Fig.7 — finished req/s and KV memory vs max_num_seqs",
        &["max_num_seqs", "finished_rps", "kv_util", "mem_util", "tok_per_gpu_s"],
    );
    let mut xs = Vec::new();
    let mut rps_series = Vec::new();
    let mut mem_series = Vec::new();
    for &mns in &sweep {
        let cfg = ServiceConfig {
            max_num_seqs: mns,
            gpu_memory: 0.9,
            max_tokens: 512,
            parallel_size: 1,
        };
        let rep = Replica::new(&A100_80G, &LLAMA2_7B, cfg);
        // saturating load so the limit is what we measure
        let mut rng = Pcg64::new(200 + mns as u64);
        let arrivals = poisson_stream(&RateProfile::constant(40.0), &mix, horizon, &mut rng);
        let res = rep.simulate(arrivals, horizon);
        let rps = res.finished_rps();
        let busy: Vec<&enova::metrics::Frame> = res
            .frames
            .iter()
            .map(|(_, f)| f)
            .filter(|f| f.n_running >= 1.0)
            .collect();
        let kv = busy.iter().map(|f| f.kv_util).sum::<f64>() / busy.len().max(1) as f64;
        let mu = busy.iter().map(|f| f.mem_util).sum::<f64>() / busy.len().max(1) as f64;
        table.row(&[
            mns.to_string(),
            format!("{rps:.2}"),
            format!("{kv:.3}"),
            format!("{mu:.3}"),
            format!("{:.0}", res.throughput_per_gpu()),
        ]);
        xs.push(mns as f64);
        rps_series.push(rps);
        mem_series.push(kv);
    }
    table.print();
    table.dump_csv("fig7_max_num_seq");
    println!(
        "{}",
        render_series("finished req/s vs max_num_seqs", &xs, &rps_series, "rps")
    );
    println!(
        "{}",
        render_series("KV utilization vs max_num_seqs", &xs, &mem_series, "kv")
    );

    // shape assertions: steep initial rise, flattening tail (the
    // KV-bandwidth asymptote is approached slowly, so we compare relative
    // growth rates rather than demanding a hard plateau), memory keeps
    // growing with diminishing throughput returns.
    let early = rps_series[1]; // mns=8
    let mid = rps_series[6]; // mns=128
    let late = *rps_series.last().unwrap(); // mns=512
    assert!(mid > 3.0 * early, "early growth missing: {early:.2}→{mid:.2}");
    assert!(
        late < 1.6 * mid,
        "tail should flatten: mid={mid:.2} late={late:.2}"
    );
    let early_gain = (mid - early) / early;
    let late_gain = (late - mid) / mid;
    assert!(
        late_gain < 0.5 * early_gain,
        "diminishing returns expected: {early_gain:.2} vs {late_gain:.2}"
    );
    assert!(
        mem_series.last().unwrap() > &(mem_series[1] * 1.5),
        "KV memory should keep growing"
    );
    println!("OK: diminishing returns + growing memory reproduced");
}
