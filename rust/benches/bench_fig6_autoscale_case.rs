//! Fig. 6 — the autoscaling case study: Mistral-7B on one RTX4090-24G at
//! gpu_memory 0.90; the request rate steps up, KV-cache utilization
//! saturates, requests pend; ENOVA detects the anomaly, localizes the KV
//! starvation (MD > 0 on kv/pending metrics), raises gpu_memory to 0.95
//! and relaunches — after which the service sustains ~1.6× the requests
//! without adding a replica.

use enova::autoscaler::{run_with_autoscaling, Action, AutoscalerOpts};
use enova::bench::{render_series, Table};
use enova::simulator::gpu::RTX4090_24G;
use enova::simulator::modelcard::MISTRAL_7B;
use enova::simulator::replica::ServiceConfig;
use enova::util::rng::Pcg64;
use enova::workload::arrivals::{poisson_stream, RateProfile};
use enova::workload::corpus::{CorpusMix, TaskFamily};

fn main() {
    let cfg = ServiceConfig {
        max_num_seqs: 48,
        gpu_memory: 0.90,
        max_tokens: 512,
        parallel_size: 1,
    };
    let mix = CorpusMix::uniform(&[TaskFamily::Gsm8k, TaskFamily::Mbpp]);
    let mut rng = Pcg64::new(42);
    // load steps up at t=1200 (the paper's 10:20 moment)
    let profile = RateProfile::step(2.0, 6.5, 1200.0);
    let arrivals = poisson_stream(&profile, &mix, 3600.0, &mut rng);

    let run = run_with_autoscaling(
        &RTX4090_24G,
        &MISTRAL_7B,
        cfg,
        arrivals,
        3600.0,
        600.0,
        &AutoscalerOpts::default(),
    );

    let times: Vec<f64> = run.frames.iter().map(|(t, _)| *t).collect();
    let kv: Vec<f64> = run.frames.iter().map(|(_, f)| f.kv_util).collect();
    let running: Vec<f64> = run.frames.iter().map(|(_, f)| f.n_running).collect();
    let pending: Vec<f64> = run.frames.iter().map(|(_, f)| f.n_pending).collect();
    println!("{}", render_series("KV cache utilization", &times, &kv, "kv"));
    println!("{}", render_series("running requests", &times, &running, "n"));
    println!("{}", render_series("pending requests", &times, &pending, "n"));

    let mut table = Table::new(
        "Fig.6 — autoscaling case study timeline",
        &["event", "value"],
    );
    table.row(&["load step at (s)".into(), "1200".into()]);
    for ev in &run.events {
        table.row(&["detected at (s)".into(), format!("{:.0}", ev.t)]);
        table.row(&["direction".into(), format!("{:?}", ev.direction)]);
        table.row(&["action".into(), format!("{:?}", ev.action)]);
        table.row(&["relaunched at (s)".into(), format!("{:.0}", ev.effective_at)]);
    }
    table.row(&["sustained rps before".into(), format!("{:.2}", run.rps_before)]);
    table.row(&["sustained rps after".into(), format!("{:.2}", run.rps_after)]);
    table.row(&[
        "ratio after/before".into(),
        format!("{:.2}x", run.rps_after / run.rps_before.max(1e-9)),
    ]);
    table.row(&["final gpu_memory".into(), format!("{:.2}", run.final_config.gpu_memory)]);
    table.print();
    table.dump_csv("fig6_autoscale_case");

    assert_eq!(run.events.len(), 1);
    assert!(matches!(run.events[0].action, Action::RaiseGpuMemory { .. }));
    let ratio = run.rps_after / run.rps_before.max(1e-9);
    println!("sustained-request ratio: {ratio:.2}x (paper: ~1.6x)");
    assert!(ratio > 1.2, "expected a clear sustained-rps gain, got {ratio:.2}");
    println!("OK: Fig.6 case study reproduced (detect → raise gpu_memory → relaunch → gain)");
}
