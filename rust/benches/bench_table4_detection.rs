//! Table IV — detection precision/recall/F1 of USAD, SDF-VAE, Uni-AD and
//! ENOVA on the 4-week labeled metric traces (train 2w / test 2w,
//! point-adjusted protocol).
//!
//! ENOVA scores with the compiled semi-supervised VAE artifact through
//! PJRT and thresholds with POT; the unsupervised baselines train in-tree
//! and get the (generous) best-F1 oracle threshold.

use enova::detect::baselines::{Detector, Scaler, SdfVae, TrainOpts, UniAd, Usad};
use enova::detect::dataset::DetectionDataset;
use enova::detect::eval;
use enova::detect::EnovaDetector;
use enova::bench::Table;
use enova::runtime::vae::VaeRuntime;
use enova::runtime::{Manifest, PjRt};

fn main() {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir).expect("run `make artifacts` first");
    let ds = DetectionDataset::load(&manifest.detection_dataset).expect("dataset");
    println!(
        "dataset: {} train rows, {} test rows, {} test anomalies (paper: 322560 / 251)",
        ds.train_rows(),
        ds.test_rows(),
        ds.test_labels.iter().filter(|&&l| l == 1).count()
    );
    let f = ds.n_features;
    let (mean, std) = ds.train_scaler();
    let scaler = Scaler { mean, std };
    let opts = TrainOpts::default();

    let mut table = Table::new(
        "Table IV — detection performance (point-adjusted)",
        &["method", "precision", "recall", "f1"],
    );
    let mut f1s: std::collections::BTreeMap<&'static str, f64> = Default::default();

    // ---- baselines (unsupervised, best-F1 threshold) -------------------
    let t0 = std::time::Instant::now();
    let detectors: Vec<Box<dyn Detector>> = vec![
        Box::new(Usad::fit(&ds.train, f, scaler.clone(), opts)),
        Box::new(SdfVae::fit(&ds.train, f, scaler.clone(), opts)),
        Box::new(UniAd::fit(&ds.train, f, scaler.clone(), opts)),
    ];
    println!("baseline training took {:.1}s", t0.elapsed().as_secs_f64());
    for det in &detectors {
        let scores = det.score_rows(&ds.test, f);
        let (_, prf) = eval::best_f1(&ds.test_labels, &scores);
        table.row(&[
            det.name().to_string(),
            format!("{:.3}", prf.precision),
            format!("{:.3}", prf.recall),
            format!("{:.3}", prf.f1),
        ]);
        f1s.insert(det.name(), prf.f1);
    }

    // ---- ENOVA (semi-supervised VAE artifact + POT threshold) ----------
    let rt = PjRt::cpu().expect("pjrt");
    let vae = VaeRuntime::load(rt, &manifest).expect("vae artifact");
    // semi-supervised calibration: POT on normal scores + labeled-anomaly
    // threshold refinement, all on the train split
    let stride = 2;
    let mut calib_rows = Vec::new();
    let mut calib_labels = Vec::new();
    for i in (0..ds.train_rows()).step_by(stride) {
        calib_rows.extend_from_slice(ds.train_row(i));
        calib_labels.push(ds.train_labels[i]);
    }
    let enova = EnovaDetector::calibrate_semisupervised(vae, &calib_rows, &calib_labels)
        .expect("calibration");
    let scores: Vec<f64> = enova
        .score(&ds.test)
        .expect("scoring")
        .into_iter()
        .map(|s| s.recon_err)
        .collect();
    let prf = eval::prf_at(&ds.test_labels, &scores, enova.threshold);
    table.row(&[
        "ENOVA".to_string(),
        format!("{:.3}", prf.precision),
        format!("{:.3}", prf.recall),
        format!("{:.3}", prf.f1),
    ]);
    f1s.insert("ENOVA", prf.f1);

    table.print();
    table.dump_csv("table4_detection");

    let enova_f1 = f1s["ENOVA"];
    let best_baseline = f1s
        .iter()
        .filter(|(k, _)| **k != "ENOVA")
        .map(|(_, v)| *v)
        .fold(0.0, f64::max);
    println!("ENOVA F1 {enova_f1:.3} vs best baseline {best_baseline:.3} (paper: 0.873 vs 0.778)");
    assert!(
        enova_f1 > best_baseline,
        "ENOVA should lead the baselines on F1"
    );
    assert!(enova_f1 > 0.7, "ENOVA F1 {enova_f1} unexpectedly low");
    println!("OK: Table IV ordering reproduced");
}
