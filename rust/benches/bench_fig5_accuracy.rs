//! Fig. 5 — accuracy (gsm8k) / pass@1 (mbpp) under ENOVA's recommended
//! max_tokens vs BASELINE (model-maximum max_tokens).
//!
//! Substitution (DESIGN.md): answer correctness is simulated as
//! base-quality × not-truncated — a request whose needed output exceeds
//! max_tokens is cut off and cannot be correct. The paper's finding is
//! that ENOVA's q99 cap truncates essentially nothing, so accuracy is
//! statistically indistinguishable from BASELINE.

use enova::bench::{scenarios, Table};
use enova::util::rng::Pcg64;
use enova::workload::corpus::TaskFamily;

fn accuracy_under(family: TaskFamily, max_tokens: usize, n: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    let mut correct = 0usize;
    for _ in 0..n {
        let needed = family.sample_output_len(&mut rng);
        let truncated = needed > max_tokens;
        let right = !truncated && rng.f64() < family.base_quality();
        correct += usize::from(right);
    }
    correct as f64 / n as f64
}

fn main() {
    let (gsm_mt, mbpp_mt) = scenarios::enova_max_tokens_per_task(11);
    let n = 20_000;

    let mut table = Table::new(
        "Fig.5 — accuracy / pass@1: ENOVA max_tokens vs BASELINE (model max)",
        &["dataset", "metric", "ENOVA(max_tokens)", "ENOVA", "BASELINE", "delta"],
    );
    let mut deltas = Vec::new();
    for (family, metric, mt) in [
        (TaskFamily::Gsm8k, "accuracy", gsm_mt),
        (TaskFamily::Mbpp, "pass@1", mbpp_mt),
    ] {
        let enova = accuracy_under(family, mt, n, 51);
        let baseline = accuracy_under(family, 4096, n, 51);
        let delta = enova - baseline;
        deltas.push(delta);
        table.row(&[
            family.name().to_string(),
            metric.to_string(),
            mt.to_string(),
            format!("{enova:.3}"),
            format!("{baseline:.3}"),
            format!("{delta:+.3}"),
        ]);
    }
    table.print();
    table.dump_csv("fig5_accuracy");

    // the paper's claim: no significant difference (we allow 2σ of the
    // binomial sampling error ≈ 2·sqrt(0.25/n) ≈ 0.007, plus the ≤1%
    // truncation mass above q99)
    for d in &deltas {
        assert!(
            d.abs() < 0.02,
            "accuracy gap {d} — ENOVA max_tokens should not hurt accuracy"
        );
    }
    println!("OK: no significant accuracy difference (paper's Fig.5 finding)");
}
