"""Artifact/manifest consistency: what the rust loader depends on."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_model_layout(manifest):
    m = manifest["model"]
    kv = m["n_layers"] * 2 * m["batch"] * m["n_heads"] * m["max_seq"] * m["head_dim"]
    assert m["kv_elems"] == kv
    assert m["state_elems"] == kv + m["batch"] * m["vocab"]


def test_artifact_files_exist(manifest):
    for name in (
        manifest["model"]["decode_file"],
        manifest["model"]["prefill_file"],
        manifest["model"]["extract_file"],
        manifest["vae"]["file"],
        manifest["embed"]["file"],
        manifest["detection_dataset"],
    ):
        assert os.path.exists(os.path.join(ART, name)), name


def test_large_constants_not_elided(manifest):
    """The HLO printer must emit full weight constants: the text parser
    silently zero-fills `{...}` placeholders, which once shipped a model
    whose every weight was zero (see aot.to_hlo_text)."""
    path = os.path.join(ART, manifest["model"]["decode_file"])
    text = open(path).read()
    assert "constant({...})" not in text
    # weights present → file is megabytes of float text
    assert os.path.getsize(path) > 5_000_000


def test_golden_outputs_present(manifest):
    g = manifest["golden"]
    assert len(g["prompt"]) == g["prompt_len"]
    assert len(g["prefill_logits_head"]) == 16
    assert len(g["decode_logits_head"]) == 16
    assert 0 <= g["prefill_argmax"] < manifest["model"]["vocab"]


def test_hlo_text_is_parseable_shape(manifest):
    """HLO text must contain a single-array ENTRY root (no tuple) so the
    rust runtime can chain buffers with execute_b."""
    for name in (manifest["model"]["decode_file"], manifest["model"]["prefill_file"]):
        with open(os.path.join(ART, name)) as f:
            text = f.read()
        assert "ENTRY" in text
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        entry_root = root_lines[-1]
        assert "tuple(" not in entry_root, entry_root


def test_vae_scaler_finite(manifest):
    v = manifest["vae"]
    assert len(v["mean"]) == v["n_features"]
    assert all(s > 0 for s in v["std"])
    assert v["test_rows"] == 322_560
