"""Detection substrate tests: synthetic traces + semi-supervised VAE."""

import numpy as np
import pytest

from compile import traces, vae


@pytest.fixture(scope="module")
def trace_set():
    return traces.generate(seed=7)


def test_trace_shape_and_cadence(trace_set):
    rows = (traces.TRAIN_DAYS + traces.TEST_DAYS) * traces.MINUTES_PER_DAY
    total = rows * traces.N_SERVICES * traces.N_REPLICAS
    assert trace_set.values.shape == (total, traces.N_METRICS)
    # the paper's test-set size: 1440 * 14 * 8 * 2 = 322 560 points
    assert int((trace_set.split == 1).sum()) == 322_560


def test_trace_anomaly_rarity(trace_set):
    te = trace_set.labels[trace_set.split == 1]
    # paper: 251 anomalous points; we require same order of magnitude
    assert 150 <= int(te.sum()) <= 400
    assert te.mean() < 0.002


def test_trace_determinism():
    a = traces.generate(seed=7)
    b = traces.generate(seed=7)
    np.testing.assert_array_equal(a.values, b.values)
    np.testing.assert_array_equal(a.labels, b.labels)
    c = traces.generate(seed=8)
    assert not np.array_equal(a.values, c.values)


def test_trace_metrics_sane(trace_set):
    v = trace_set.values
    names = traces.METRIC_NAMES
    assert np.all(v[:, names.index("mem_util")] <= 1.0)
    assert np.all(v[:, names.index("gpu_util")] <= 1.0)
    assert np.all(v[:, names.index("n_pending")] >= 0.0)
    assert np.all(v[:, names.index("t_request")] > 0.0)
    assert not np.isnan(v).any()


def test_overload_anomalies_have_pending_queues(trace_set):
    lab = trace_set.labels == 1
    pend = trace_set.values[:, traces.METRIC_NAMES.index("n_pending")]
    # anomalous minutes carry far more queueing than normal ones on average
    assert pend[lab].mean() > 5 * pend[~lab].mean()


@pytest.fixture(scope="module")
def trained(trace_set):
    tr_x, tr_l, _, _ = traces.train_test(trace_set)
    cfg = vae.VaeConfig(epochs=4)
    # stride for test speed; full training happens in aot.py
    return vae.train(tr_x[::8], tr_l[::8], cfg), cfg


def test_vae_loss_decreases(trained):
    result, _ = trained
    assert result.losses[-1] < result.losses[0]


def test_vae_beta_stays_bounded(trained):
    result, cfg = trained
    assert all(cfg.beta_min <= b <= cfg.beta_max for b in result.betas)


def test_vae_separates_anomalies(trained, trace_set):
    result, _ = trained
    _, _, te_x, te_l = traces.train_test(trace_set)
    kl, _ = vae.score_numpy(result, te_x[::20])
    lab = te_l[::20]
    assert kl[lab == 1].mean() > 1.5 * kl[lab == 0].mean()


def test_vae_scorer_layout(trained):
    result, cfg = trained
    scorer = vae.make_scorer(result, cfg, batch=16)
    x = result.mean[None, :].repeat(16, axis=0).astype(np.float32)
    out = np.asarray(scorer(x))
    assert out.shape == (16, cfg.n_features + 1)
    kl_direct, recon_direct = vae.score_numpy(result, x)
    np.testing.assert_allclose(out[:, -1], kl_direct, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out[:, :-1], recon_direct, rtol=1e-4, atol=1e-4)


def test_csv_roundtrip(tmp_path, trace_set):
    path = tmp_path / "d.csv"
    traces.write_csv(trace_set, str(path))
    with open(path) as f:
        header = f.readline().strip().split(",")
    assert header == ["instance", "split", "label"] + traces.METRIC_NAMES
    data = np.loadtxt(path, delimiter=",", skiprows=1, max_rows=100)
    assert data.shape[1] == 3 + traces.N_METRICS
