"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; the kernels must match ``ref.py`` to f32 tolerance
on every draw. This is the core correctness signal for the compiled model —
if these pass, the decode path in the HLO artifact computes real attention.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention
from compile.kernels.fused_ffn import fused_ffn

RTOL, ATOL = 1e-5, 1e-5


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 6),
    h=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 4),
    block_k=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s_blocks, block_k, d, seed):
    rng = np.random.default_rng(seed)
    s = s_blocks * block_k
    q = rand(rng, b, h, d)
    k = rand(rng, b, h, s, d)
    v = rand(rng, b, h, s, d)
    lens = jnp.asarray(rng.integers(0, s + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=block_k)
    expect = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("lens", [[0, 0], [1, 0], [64, 64], [63, 1]])
def test_decode_attention_length_edges(lens):
    rng = np.random.default_rng(0)
    b, h, s, d = 2, 2, 64, 16
    q, k, v = rand(rng, b, h, d), rand(rng, b, h, s, d), rand(rng, b, h, s, d)
    lens = jnp.asarray(lens, jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=32)
    expect = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)
    # fully-masked rows must be exactly zero, not NaN
    assert not np.isnan(np.asarray(out)).any()


def test_decode_attention_ignores_padding_values():
    """Garbage beyond seq_len must not influence the output."""
    rng = np.random.default_rng(3)
    b, h, s, d = 2, 2, 64, 16
    q, k, v = rand(rng, b, h, d), rand(rng, b, h, s, d), rand(rng, b, h, s, d)
    lens = jnp.asarray([10, 37], jnp.int32)
    out1 = decode_attention(q, k, v, lens, block_k=16)
    k2 = k.at[:, :, 40:, :].set(1e6)
    v2 = v.at[:, :, 40:, :].set(-1e6)
    out2 = decode_attention(q, k2, v2, lens, block_k=16)
    np.testing.assert_allclose(out1, out2, rtol=0, atol=0)


def test_decode_attention_rejects_bad_block():
    rng = np.random.default_rng(0)
    q, k, v = rand(rng, 1, 1, 8), rand(rng, 1, 1, 48, 8), rand(rng, 1, 1, 48, 8)
    with pytest.raises(ValueError):
        decode_attention(q, k, v, jnp.asarray([4], jnp.int32), block_k=64)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block_n=st.sampled_from([2, 4, 8]),
    dm=st.sampled_from([32, 64, 128]),
    f_blocks=st.integers(1, 4),
    block_f=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_ffn_matches_ref(n_blocks, block_n, dm, f_blocks, block_f, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * block_n
    dff = f_blocks * block_f
    x = rand(rng, n, dm)
    wg = rand(rng, dm, dff, scale=dm**-0.5)
    wu = rand(rng, dm, dff, scale=dm**-0.5)
    wd = rand(rng, dff, dm, scale=dff**-0.5)
    out = fused_ffn(x, wg, wu, wd, block_n=block_n, block_f=block_f)
    expect = ref.fused_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_fused_ffn_rejects_bad_tiling():
    rng = np.random.default_rng(0)
    x = rand(rng, 6, 32)
    w = rand(rng, 32, 128)
    wd = rand(rng, 128, 32)
    with pytest.raises(ValueError):
        fused_ffn(x, w, w, wd, block_n=4, block_f=128)


def test_full_attention_ref_is_causal():
    """Oracle invariant: output at position p is independent of tokens > p."""
    rng = np.random.default_rng(5)
    h, s, d = 2, 16, 8
    q, k, v = rand(rng, h, s, d), rand(rng, h, s, d), rand(rng, h, s, d)
    out1 = ref.full_attention_ref(q, k, v, jnp.int32(s))
    k2 = k.at[:, 9:, :].add(3.0)
    v2 = v.at[:, 9:, :].add(-2.0)
    out2 = ref.full_attention_ref(q, k2, v2, jnp.int32(s))
    np.testing.assert_allclose(out1[:, :9], out2[:, :9], rtol=1e-6, atol=1e-6)
