"""Embedder tests: hashing determinism + task separability (Fig. 8 premise)."""

import numpy as np

from compile import embedder

TEMPLATES = {
    "math": "Solve the following grade school math problem step by step: {}",
    "code": "Write a python function to {} and return the result.",
    "arc": "Choose the correct answer to this science question: {}",
    "reading": "Read the story and answer: {}",
}


def embed_texts(texts):
    fn = embedder.make_embed_fn()
    feats = np.stack([embedder.hash_ngrams(t) for t in texts]).astype(np.float32)
    pad = (-len(feats)) % embedder.EMBED_BATCH
    if pad:
        feats = np.vstack([feats, np.zeros((pad, embedder.HASH_DIM), np.float32)])
    out = []
    for i in range(0, len(feats), embedder.EMBED_BATCH):
        out.append(np.asarray(fn(feats[i : i + embedder.EMBED_BATCH])))
    return np.concatenate(out)[: len(texts)]


def test_hash_deterministic():
    a = embedder.hash_ngrams("compute the minimum cost path")
    b = embedder.hash_ngrams("compute the minimum cost path")
    np.testing.assert_array_equal(a, b)
    assert a.shape == (embedder.HASH_DIM,)
    assert abs(a.sum() - 1.0) < 1e-5


def test_hash_known_vector():
    """Pin the FNV-1a n-gram hash so the rust mirror can assert equality."""
    v = embedder.hash_ngrams("abc")
    (idx,) = np.nonzero(v)
    # single trigram "abc" → one bucket with weight 1
    assert len(idx) == 1 and v[idx[0]] == 1.0
    assert idx[0] == 843  # FNV-1a("abc") % 1024 (mirrored in rust tests)


def test_embeddings_unit_norm():
    e = embed_texts(["hello world", "another request"])
    np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, rtol=1e-5)


def test_same_task_closer_than_cross_task():
    rng = np.random.default_rng(0)
    texts, labels = [], []
    fillers = ["alpha beta", "gamma delta", "epsilon zeta", "eta theta", "iota kappa"]
    for li, (name, tpl) in enumerate(TEMPLATES.items()):
        for f in fillers:
            texts.append(tpl.format(f))
            labels.append(li)
    e = embed_texts(texts)
    labels = np.asarray(labels)
    sims = e @ e.T
    intra, inter = [], []
    for i in range(len(texts)):
        for j in range(i + 1, len(texts)):
            (intra if labels[i] == labels[j] else inter).append(sims[i, j])
    assert np.mean(intra) > np.mean(inter) + 0.2
