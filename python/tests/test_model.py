"""L2 correctness: prefill/decode state-carry model vs full-forward oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    ModelConfig,
    decode_step,
    full_forward_logits,
    init_params,
    make_entry_points,
    prefill,
)

CFG = ModelConfig(batch=4, max_seq=128)
PARAMS = init_params(CFG, seed=0)


def empty_state():
    return jnp.zeros((CFG.state_elems,), jnp.float32)


def logits_of(state):
    return np.asarray(state[: CFG.batch * CFG.vocab].reshape(CFG.batch, CFG.vocab))


def test_config_layout():
    assert CFG.state_elems == CFG.kv_elems + CFG.batch * CFG.vocab
    assert CFG.param_count > 1_000_000  # the served model is a real network


def test_prefill_matches_full_forward():
    rng = np.random.default_rng(1)
    toks = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    plen = 17
    st_ = prefill(empty_state(), jnp.asarray(toks), jnp.int32(plen), jnp.int32(2), PARAMS, CFG)
    full = full_forward_logits(jnp.asarray(toks), jnp.int32(plen), PARAMS, CFG)
    np.testing.assert_allclose(
        logits_of(st_)[2], np.asarray(full[plen - 1]), rtol=1e-4, atol=1e-4
    )


def test_prefill_preserves_other_slots():
    rng = np.random.default_rng(2)
    toks1 = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    toks2 = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    s1 = prefill(empty_state(), jnp.asarray(toks1), jnp.int32(9), jnp.int32(0), PARAMS, CFG)
    s2 = prefill(s1, jnp.asarray(toks2), jnp.int32(21), jnp.int32(3), PARAMS, CFG)
    # slot 0's logits and KV must be untouched by the second prefill
    np.testing.assert_array_equal(logits_of(s2)[0], logits_of(s1)[0])
    kv1 = np.asarray(s1[CFG.batch * CFG.vocab :]).reshape(
        CFG.n_layers, 2, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim
    )
    kv2 = np.asarray(s2[CFG.batch * CFG.vocab :]).reshape(kv1.shape)
    np.testing.assert_array_equal(kv2[:, :, 0], kv1[:, :, 0])


@settings(max_examples=6, deadline=None)
@given(
    plen=st.integers(1, 100),
    slot=st.integers(0, CFG.batch - 1),
    steps=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_equals_full_forward(plen, slot, steps, seed):
    """prefill(prompt) + n × decode == full forward on prompt+n tokens."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    state = prefill(
        empty_state(), jnp.asarray(toks), jnp.int32(plen), jnp.int32(slot), PARAMS, CFG
    )
    for i in range(min(steps, CFG.max_seq - plen - 1)):
        tk = np.zeros(CFG.batch, np.int32)
        sl = np.zeros(CFG.batch, np.int32)
        tk[slot] = toks[plen + i]
        sl[slot] = plen + i
        state = decode_step(state, jnp.asarray(tk), jnp.asarray(sl), PARAMS, CFG)
        full = full_forward_logits(jnp.asarray(toks), jnp.int32(plen + i + 1), PARAMS, CFG)
        np.testing.assert_allclose(
            logits_of(state)[slot], np.asarray(full[plen + i]), rtol=2e-4, atol=2e-4
        )


def test_decode_inactive_slots_untouched():
    rng = np.random.default_rng(4)
    toks = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    state = prefill(empty_state(), jnp.asarray(toks), jnp.int32(8), jnp.int32(1), PARAMS, CFG)
    kv_before = np.asarray(state[CFG.batch * CFG.vocab :]).reshape(
        CFG.n_layers, 2, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim
    )
    tk = np.zeros(CFG.batch, np.int32)
    sl = np.zeros(CFG.batch, np.int32)  # all inactive (len 0)
    out = decode_step(state, jnp.asarray(tk), jnp.asarray(sl), PARAMS, CFG)
    kv_after = np.asarray(out[CFG.batch * CFG.vocab :]).reshape(kv_before.shape)
    np.testing.assert_array_equal(kv_after, kv_before)
    assert np.all(logits_of(out) == 0.0)


def test_decode_two_sequences_independent():
    """Batching must not couple sequences: slot outputs match solo runs."""
    rng = np.random.default_rng(5)
    t1 = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    t2 = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    p1, p2 = 11, 29

    def run(assignments):
        state = empty_state()
        for toks, plen, slot in assignments:
            state = prefill(
                state, jnp.asarray(toks), jnp.int32(plen), jnp.int32(slot), PARAMS, CFG
            )
        tk = np.zeros(CFG.batch, np.int32)
        sl = np.zeros(CFG.batch, np.int32)
        for toks, plen, slot in assignments:
            tk[slot] = toks[plen]
            sl[slot] = plen
        return logits_of(decode_step(state, jnp.asarray(tk), jnp.asarray(sl), PARAMS, CFG))

    both = run([(t1, p1, 0), (t2, p2, 3)])
    solo1 = run([(t1, p1, 0)])
    solo2 = run([(t2, p2, 3)])
    np.testing.assert_allclose(both[0], solo1[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(both[3], solo2[3], rtol=1e-4, atol=1e-4)


def test_pallas_and_ref_paths_agree():
    rng = np.random.default_rng(6)
    toks = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    state = prefill(empty_state(), jnp.asarray(toks), jnp.int32(30), jnp.int32(0), PARAMS, CFG)
    tk = np.zeros(CFG.batch, np.int32)
    sl = np.zeros(CFG.batch, np.int32)
    tk[0] = toks[30]
    sl[0] = 30
    a = decode_step(state, jnp.asarray(tk), jnp.asarray(sl), PARAMS, CFG, use_pallas=True)
    b = decode_step(state, jnp.asarray(tk), jnp.asarray(sl), PARAMS, CFG, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_entry_points_jittable():
    decode_fn, prefill_fn = make_entry_points(CFG, PARAMS)
    import jax

    rng = np.random.default_rng(7)
    toks = rng.integers(0, CFG.vocab, CFG.max_seq).astype(np.int32)
    st_ = jax.jit(prefill_fn)(
        empty_state(), jnp.asarray(toks), jnp.int32(5), jnp.int32(0)
    )
    out = jax.jit(decode_fn)(
        st_,
        jnp.asarray(np.zeros(CFG.batch, np.int32)),
        jnp.asarray(np.array([5, 0, 0, 0], np.int32)),
    )
    assert out.shape == (CFG.state_elems,)
    assert not np.isnan(np.asarray(out)).any()
