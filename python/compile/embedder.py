"""Request-text embedder (stand-in for bge-large-en, DESIGN.md §Substitutions).

The paper embeds request text with bge-large-en before community detection
(§IV-A-3). That checkpoint is unavailable offline, so we use the classic
feature-hashing construction: the rust side hashes character n-grams of the
request into a ``HASH_DIM`` count vector (``clusterer::features`` — the same
hash function is mirrored in ``python/tests/test_embedder.py``), and this
module provides the dense half: a fixed random projection + tanh + L2
normalization, lowered to ``artifacts/embed.hlo.txt``.

Johnson–Lindenstrauss gives distance preservation, so "same task template ⇒
nearby, different task ⇒ separated" — the only property clustering needs —
survives the substitution.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

HASH_DIM = 1024
EMBED_DIM = 64
EMBED_BATCH = 32
PROJ_SEED = 11


def projection_matrix() -> np.ndarray:
    rng = np.random.default_rng(PROJ_SEED)
    return rng.normal(0.0, 1.0 / np.sqrt(HASH_DIM), (HASH_DIM, EMBED_DIM)).astype(
        np.float32
    )


def make_embed_fn():
    w = jnp.asarray(projection_matrix())

    def embed(x):
        """``x`` f32[B, HASH_DIM] (l1-normalized n-gram counts) → f32[B, EMBED_DIM]."""
        y = jnp.tanh(x @ w * 8.0)
        norm = jnp.sqrt(jnp.sum(y * y, axis=-1, keepdims=True))
        return y / jnp.maximum(norm, 1e-9)

    return embed


def hash_ngrams(text: str, n: int = 3) -> np.ndarray:
    """FNV-1a character-n-gram feature hashing.

    Mirrored bit-for-bit by rust ``clusterer::features::hash_ngrams`` — the
    cross-language agreement is asserted in tests on both sides.
    """
    v = np.zeros(HASH_DIM, dtype=np.float32)
    data = text.lower().encode("utf-8")
    if len(data) < n:
        data = data + b" " * (n - len(data))
    for i in range(len(data) - n + 1):
        h = np.uint64(0xCBF29CE484222325)
        for b in data[i : i + n]:
            h = np.uint64((int(h) ^ b) * 0x100000001B3 % (1 << 64))
        v[int(h % HASH_DIM)] += 1.0
    s = v.sum()
    return v / s if s > 0 else v
