"""Pallas flash-decode attention kernel (L1).

The serving hot-spot: one query token per sequence attends over that
sequence's KV cache. This is the TPU re-think of vLLM's PagedAttention CUDA
kernel (DESIGN.md §Hardware-Adaptation):

* CUDA assigns a threadblock per (sequence, head) and strides warps over KV
  pages in shared memory. Here the Pallas **grid** is ``(B, H, S/block_k)``
  and ``BlockSpec`` index maps express the HBM→VMEM tile schedule.
* The softmax is computed **online** (flash-decoding): each KV block updates
  a running max ``m``, normalizer ``l`` and accumulator ``o`` that live in
  the revisited output blocks, so only ``(block_k, D)`` KV tiles are resident
  in VMEM at a time. VMEM footprint per grid step is
  ``(2*block_k*D + 2*D + 2) * 4`` bytes — e.g. 16.5 KiB for ``block_k=64,
  D=32`` — far below the ~16 MiB VMEM budget, leaving room for the MXU
  pipeline to double-buffer tiles.
* Length masking replaces the paged block table: the L3 KV manager keeps the
  logical paging; the kernel sees a dense padded cache plus ``seq_lens``.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Correctness is
pinned to ``ref.decode_attention_ref`` by the hypothesis sweep in
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attention_kernel(
    lens_ref,  # [1] int32 — seq_lens[b]
    q_ref,  # [1, 1, D]
    k_ref,  # [1, 1, block_k, D]
    v_ref,  # [1, 1, block_k, D]
    o_ref,  # [1, 1, D] accumulator, revisited across the kv-block grid dim
    m_ref,  # [1, 1] running max
    l_ref,  # [1, 1] running normalizer
    *,
    block_k: int,
    num_blocks: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :]  # [D]
    k = k_ref[0, 0, :, :]  # [block_k, D]
    v = v_ref[0, 0, :, :]  # [block_k, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))

    # Scores for this KV tile, with validity masking (flash-decoding step).
    offs = j * block_k + jnp.arange(block_k, dtype=jnp.int32)
    valid = offs < lens_ref[0]
    s = (k @ q) * scale  # [block_k]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    m_new = jnp.maximum(m_new, NEG_INF)  # stay finite on fully-masked tiles
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # [block_k]
    alpha = jnp.exp(m_prev - m_new)

    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p)
    o_ref[0, 0, :] = o_ref[0, 0, :] * alpha + p @ v
    m_ref[0, 0] = m_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        o_ref[0, 0, :] = o_ref[0, 0, :] / jnp.maximum(l_ref[0, 0], 1e-9)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    seq_lens: jnp.ndarray,
    *,
    block_k: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """Flash-decode attention. Shapes as in ``ref.decode_attention_ref``.

    ``S`` must be a multiple of ``block_k`` (the L3 engine always compiles
    power-of-two caches); smaller caches simply pass a smaller ``block_k``.
    """
    b, h, d = q.shape
    s = k.shape[2]
    if s % block_k != 0:
        raise ValueError(f"S={s} not a multiple of block_k={block_k}")
    num_blocks = s // block_k

    kernel = functools.partial(
        _decode_attention_kernel, block_k=block_k, num_blocks=num_blocks
    )
    out, _, _ = pl.pallas_call(
        kernel,
        grid=(b, h, num_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, t: (i,)),
            pl.BlockSpec((1, 1, d), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda i, j, t: (i, j, t, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda i, j, t: (i, j, t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
        ],
        interpret=interpret,
    )(seq_lens, q, k, v)
    return out
