"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float32 tolerance across the shape/dtype sweep in
``python/tests/test_kernels.py`` (hypothesis-driven). They are also used
directly by the prefill path of the L2 model, where standard full-sequence
attention is fine.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    seq_lens: jnp.ndarray,
) -> jnp.ndarray:
    """Single-query (decode-phase) attention over a padded KV cache.

    Args:
      q: ``[B, H, D]`` query for the token being generated.
      k: ``[B, H, S, D]`` key cache (padded to ``S``).
      v: ``[B, H, S, D]`` value cache (padded to ``S``).
      seq_lens: ``[B]`` int32, number of valid cache positions per sequence.
        Positions ``>= seq_lens[b]`` are masked out. ``seq_lens[b] == 0``
        yields a zero output row (inactive slot).

    Returns:
      ``[B, H, D]`` attention output.
    """
    b, h, s, d = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    # [B, H, S]
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    pos = jnp.arange(s, dtype=jnp.int32)
    mask = pos[None, None, :] < seq_lens[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # keep finite for fully-masked rows
    p = jnp.where(mask, jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", p, v)
    return out / jnp.maximum(l, 1e-9)


def fused_ffn_ref(
    x: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
) -> jnp.ndarray:
    """SwiGLU feed-forward: ``silu(x @ w_gate) * (x @ w_up) @ w_down``.

    Args:
      x: ``[N, d_model]`` activations.
      w_gate / w_up: ``[d_model, d_ff]``.
      w_down: ``[d_ff, d_model]``.
    """
    g = x @ w_gate
    u = x @ w_up
    act = g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u  # silu(g) * u
    return act @ w_down


def full_attention_ref(
    x_q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    prompt_len: jnp.ndarray,
) -> jnp.ndarray:
    """Causal full-sequence attention used by the prefill path.

    Args:
      x_q: ``[H, S, D]`` queries for all prompt positions.
      k, v: ``[H, S, D]`` keys/values for all prompt positions.
      prompt_len: scalar int32; positions ``>= prompt_len`` are padding.

    Returns:
      ``[H, S, D]``.
    """
    h, s, d = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=x_q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", x_q, k) * scale
    pos = jnp.arange(s, dtype=jnp.int32)
    causal = pos[None, :, None] >= pos[None, None, :]
    valid = pos[None, None, :] < prompt_len
    mask = jnp.logical_and(causal, valid)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v) / jnp.maximum(l, 1e-9)
