"""Pallas fused SwiGLU feed-forward kernel (L1).

Fuses ``silu(x @ w_gate) * (x @ w_up) @ w_down`` into a single kernel so the
``[N, d_ff]`` intermediate never materializes in HBM — the GPU version of
this trick keeps the intermediate in registers/shared memory; on TPU the
equivalent is a VMEM-resident ``(block_n, block_f)`` tile that is consumed by
the down-projection matmul in the same grid step (DESIGN.md
§Hardware-Adaptation).

Tiling: grid ``(N/block_n, d_ff/block_f)``. Each step loads an activation
tile ``[block_n, d_model]``, weight tiles ``[d_model, block_f]`` /
``[block_f, d_model]``, and accumulates partial down-projections into the
revisited output tile. All three matmuls are MXU-shaped (inner dims are the
full ``d_model``/``block_f``, multiples of 128/64 in the shipped configs).
VMEM per step for the default ``block_n=8, block_f=128, d_model=128`` config:
(8*128 + 2*128*128 + 128*128 + 8*128 + 8*128)*4B ≈ 208 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, num_f_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [block_n, d_model]
    g = x @ wg_ref[...]  # [block_n, block_f]
    u = x @ wu_ref[...]  # [block_n, block_f]
    act = g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u  # silu(g) * u
    o_ref[...] += act @ wd_ref[...]  # [block_n, d_model]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_f", "interpret")
)
def fused_ffn(
    x: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    *,
    block_n: int = 8,
    block_f: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused SwiGLU FFN. Shapes as in ``ref.fused_ffn_ref``.

    ``N`` must be a multiple of ``block_n`` and ``d_ff`` of ``block_f``.
    """
    n, d_model = x.shape
    d_ff = w_gate.shape[1]
    if n % block_n != 0:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")
    if d_ff % block_f != 0:
        raise ValueError(f"d_ff={d_ff} not a multiple of block_f={block_f}")
    grid = (n // block_n, d_ff // block_f)

    kernel = functools.partial(_fused_ffn_kernel, num_f_blocks=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_model), lambda i, j: (i, 0)),
            pl.BlockSpec((d_model, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((d_model, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, d_model), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d_model), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_model), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
