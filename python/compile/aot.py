"""AOT entry point: lower every L2 program to HLO text + write the manifest.

Run once by ``make artifacts``; python never runs on the request path.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Everything is lowered with ``return_tuple=False`` so each program has a
single array root — that lets the rust runtime chain outputs back into
inputs as device-resident ``PjRtBuffer``s (``execute_b``) without tuple
unpacking on the host.

Artifacts:
  model.decode / model.prefill  — state-carry LM programs (weights baked)
  vae_score                     — trained detection VAE scorer
  embed                         — request-embedding projection
  manifest.json                 — dims/offsets/files for the rust loader
  detection_dataset.csv         — labeled 4-week metric traces (Table IV)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import embedder, traces, vae
from .model import ModelConfig, init_params, make_entry_points


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    # print_large_constants is essential: the default printer elides big
    # constants as `{...}`, which the HLO text parser silently reads back
    # as zeros — i.e. the baked model weights would vanish.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the image's xla_extension 0.5.1 parser predates source_end_line/
    # source_end_column metadata — strip metadata entirely
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_to_file(fn, args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build(out_dir: str, seed: int = 0, quick: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    manifest: dict = {"version": 1, "seed": seed}

    # ---- L2 model (uses the L1 Pallas kernels) -------------------------
    cfg = ModelConfig()
    params = init_params(cfg, seed=seed)
    decode_fn, prefill_fn = make_entry_points(cfg, params)

    decode_file = f"decode_b{cfg.batch}_s{cfg.max_seq}.hlo.txt"
    prefill_file = f"prefill_s{cfg.max_seq}.hlo.txt"
    n1 = lower_to_file(
        decode_fn,
        (f32(cfg.state_elems), i32(cfg.batch), i32(cfg.batch)),
        os.path.join(out_dir, decode_file),
    )
    n2 = lower_to_file(
        prefill_fn,
        (f32(cfg.state_elems), i32(cfg.max_seq), i32(), i32()),
        os.path.join(out_dir, prefill_file),
    )
    # Auxiliary extractor: the CPU PJRT plugin doesn't implement
    # CopyRawToHost, so the rust side reads logits by running this tiny
    # program on the device-resident state and materializing only its
    # B×V output (the KV cache never crosses the host boundary).
    extract_file = "extract_logits.hlo.txt"

    def extract_logits(state):
        return state[: cfg.logits_elems].reshape(cfg.batch, cfg.vocab)

    lower_to_file(
        extract_logits, (f32(cfg.state_elems),), os.path.join(out_dir, extract_file)
    )

    manifest["model"] = {
        "decode_file": decode_file,
        "prefill_file": prefill_file,
        "extract_file": extract_file,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq,
        "batch": cfg.batch,
        "kv_elems": cfg.kv_elems,
        "layout": "logits_first",
        "state_elems": cfg.state_elems,
        "param_count": cfg.param_count,
    }
    print(f"[aot] model lowered ({n1 + n2} chars) in {time.time()-t0:.1f}s")

    # ---- golden outputs: pin the python→HLO→rust numeric bridge -------
    # A fixed prompt prefilled into slot 1 followed by one decode step;
    # rust/tests/runtime_golden.rs must reproduce these logits bit-close.
    rng = np.random.default_rng(123)
    plen = 12
    toks = rng.integers(3, cfg.vocab, size=cfg.max_seq).astype(np.int32)
    state = jnp.zeros((cfg.state_elems,), jnp.float32)
    state = jax.jit(prefill_fn)(state, jnp.asarray(toks), jnp.int32(plen), jnp.int32(1))
    logits_prefill = np.asarray(state[:cfg.logits_elems]).reshape(cfg.batch, cfg.vocab)[1]
    dt = np.zeros(cfg.batch, np.int32)
    dl = np.zeros(cfg.batch, np.int32)
    dt[1] = int(np.argmax(logits_prefill))
    dl[1] = plen
    state = jax.jit(decode_fn)(state, jnp.asarray(dt), jnp.asarray(dl))
    logits_decode = np.asarray(state[:cfg.logits_elems]).reshape(cfg.batch, cfg.vocab)[1]
    manifest["golden"] = {
        "prompt": [int(t) for t in toks[:plen]],
        "prompt_len": plen,
        "slot": 1,
        "prefill_argmax": int(np.argmax(logits_prefill)),
        "prefill_logits_head": [float(x) for x in logits_prefill[:16]],
        "decode_token": int(dt[1]),
        "decode_argmax": int(np.argmax(logits_decode)),
        "decode_logits_head": [float(x) for x in logits_decode[:16]],
    }

    # ---- detection traces + VAE ---------------------------------------
    t1 = time.time()
    ts = traces.generate(seed=7)
    csv_path = os.path.join(out_dir, "detection_dataset.csv")
    traces.write_csv(ts, csv_path)
    tr_x, tr_l, te_x, te_l = traces.train_test(ts)
    vcfg = vae.VaeConfig(epochs=3 if quick else 30)
    result = vae.train(tr_x, tr_l, vcfg)
    scorer = vae.make_scorer(result, vcfg, batch=256)
    vae_file = "vae_score.hlo.txt"
    lower_to_file(scorer, (f32(256, vcfg.n_features),), os.path.join(out_dir, vae_file))
    manifest["vae"] = {
        "file": vae_file,
        "batch": 256,
        "n_features": vcfg.n_features,
        "metric_names": traces.METRIC_NAMES,
        "train_rows": int(len(tr_x)),
        "test_rows": int(len(te_x)),
        "test_anomalies": int(te_l.sum()),
        "final_loss": float(result.losses[-1]),
        "mean": [float(v) for v in result.mean],
        "std": [float(v) for v in result.std],
    }
    manifest["detection_dataset"] = "detection_dataset.csv"
    print(
        f"[aot] traces+vae done in {time.time()-t1:.1f}s "
        f"(train={len(tr_x)} test={len(te_x)} anomalies={int(te_l.sum())}, "
        f"final loss {result.losses[-1]:.3f})"
    )

    # ---- request embedder ----------------------------------------------
    embed_fn = embedder.make_embed_fn()
    embed_file = "embed.hlo.txt"
    lower_to_file(
        embed_fn,
        (f32(embedder.EMBED_BATCH, embedder.HASH_DIM),),
        os.path.join(out_dir, embed_file),
    )
    manifest["embed"] = {
        "file": embed_file,
        "batch": embedder.EMBED_BATCH,
        "hash_dim": embedder.HASH_DIM,
        "embed_dim": embedder.EMBED_DIM,
    }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] all artifacts written to {out_dir} in {time.time()-t0:.1f}s")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="fast VAE training (tests)")
    args = ap.parse_args()
    build(args.out, seed=args.seed, quick=args.quick)


if __name__ == "__main__":
    main()
