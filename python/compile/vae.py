"""Semi-supervised VAE performance-detection model (paper §IV-B, eq. 9).

The model hypothesizes that normal metric vectors ``m`` are generated from a
latent multivariate Gaussian ``z``; anomalies deviate. Training optimizes the
*labeled* ELBO of eq. 9:

    L = mean_i [ l_i · E_q[log p(m|z)] − (1+l_i)/2 · β(k) · KL(q(z|m) ‖ p(z)) ]

with l_i ∈ {+1, −1}: normal points (+1) get the standard ELBO, the few
labeled anomalies (−1) get their reconstruction likelihood *pushed down*
(and no KL pull), letting them carve the boundary of the normal manifold —
the semi-supervised trick of Huang et al. (WWW'22) the paper builds on.
β(k) follows a PI controller (ControlVAE-style) that servos the KL term
toward a setpoint so the objective converges instead of posterior-collapsing.

Training happens once, at artifact-build time, on the synthetic trace
trainset; the trained scorer is lowered to ``artifacts/vae_score.hlo.txt``
with weights baked. At inference the scorer is deterministic (uses the
posterior mean) and returns ``[recon ‖ kl]`` so the rust detector can apply
the POT threshold to the KL column and the mean-difference (MD) scale-up/down
rule to the reconstruction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class VaeConfig:
    n_features: int = 8
    hidden: int = 48
    latent: int = 8
    epochs: int = 30
    batch: int = 512
    lr: float = 2e-3
    kl_setpoint: float = 3.0  # nats; PI controller target for the KL term
    beta_init: float = 0.2
    beta_min: float = 1e-3
    beta_max: float = 1.0
    kp: float = 0.01
    ki: float = 0.0008
    anomaly_weight: float = 0.2  # scale of the push-away term
    seed: int = 3


def init_vae(cfg: VaeConfig, seed: int | None = None) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(cfg.seed if seed is None else seed)

    def mat(a, b):
        return jnp.asarray(rng.normal(0, 1.0 / np.sqrt(a), (a, b)), jnp.float32)

    f, h, z = cfg.n_features, cfg.hidden, cfg.latent
    return {
        "enc_w1": mat(f, h), "enc_b1": jnp.zeros((h,), jnp.float32),
        "enc_mu": mat(h, z), "enc_mu_b": jnp.zeros((z,), jnp.float32),
        "enc_lv": mat(h, z), "enc_lv_b": jnp.full((z,), -1.0, jnp.float32),
        "dec_w1": mat(z, h), "dec_b1": jnp.zeros((h,), jnp.float32),
        "dec_w2": mat(h, f), "dec_b2": jnp.zeros((f,), jnp.float32),
        "dec_lv": jnp.zeros((f,), jnp.float32),  # learned obs log-variance
    }


def encode(p, m):
    h = jnp.tanh(m @ p["enc_w1"] + p["enc_b1"])
    mu = h @ p["enc_mu"] + p["enc_mu_b"]
    logvar = jnp.clip(h @ p["enc_lv"] + p["enc_lv_b"], -8.0, 4.0)
    return mu, logvar


def decode(p, z):
    h = jnp.tanh(z @ p["dec_w1"] + p["dec_b1"])
    return h @ p["dec_w2"] + p["dec_b2"]


def kl_to_prior(mu, logvar):
    """KL(q(z|m) ‖ N(0, I)) per point."""
    return 0.5 * jnp.sum(jnp.exp(logvar) + mu**2 - 1.0 - logvar, axis=-1)


def log_px(p, m, recon):
    lv = jnp.clip(p["dec_lv"], -6.0, 4.0)
    return -0.5 * jnp.sum(
        (m - recon) ** 2 * jnp.exp(-lv) + lv + jnp.log(2 * jnp.pi), axis=-1
    )


def loss_fn(p, m, labels, beta, key, cfg: VaeConfig):
    """Negative eq. 9 (we minimize). ``labels`` ∈ {+1, −1}."""
    mu, logvar = encode(p, m)
    eps = jax.random.normal(key, mu.shape)
    z = mu + jnp.exp(0.5 * logvar) * eps
    recon = decode(p, z)
    lp = log_px(p, m, recon)
    kl = kl_to_prior(mu, logvar)
    normal = (labels > 0).astype(jnp.float32)
    anom = 1.0 - normal
    # l_i·E[log p] − (1+l_i)/2·β·KL ; anomaly log-lik clipped so a single
    # labeled point cannot dominate the objective.
    elbo = (
        normal * (lp - beta * kl)
        - anom * cfg.anomaly_weight * jnp.clip(lp, -50.0, 50.0)
    )
    mean_kl = jnp.sum(normal * kl) / jnp.maximum(jnp.sum(normal), 1.0)
    return -jnp.mean(elbo), mean_kl


@dataclasses.dataclass
class TrainResult:
    params: Dict[str, jnp.ndarray]
    mean: np.ndarray
    std: np.ndarray
    losses: list
    betas: list


def train(
    values: np.ndarray,
    labels01: np.ndarray,
    cfg: VaeConfig = VaeConfig(),
) -> TrainResult:
    """Train on the trace trainset. ``labels01``: 1 = anomaly, 0 = normal."""
    mean = values.mean(axis=0)
    std = values.std(axis=0) + 1e-6
    x = ((values - mean) / std).astype(np.float32)
    lab = np.where(labels01 > 0, -1.0, 1.0).astype(np.float32)

    params = init_vae(cfg)
    opt = {k: (jnp.zeros_like(v), jnp.zeros_like(v)) for k, v in params.items()}

    grad_fn = jax.jit(
        jax.value_and_grad(
            lambda p, m, l, b, k: loss_fn(p, m, l, b, k, cfg), has_aux=True
        )
    )

    @jax.jit
    def adam_step(params, opt, grads, step):
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_p, new_o = {}, {}
        for k in params:
            m1, m2 = opt[k]
            g = grads[k]
            m1 = b1 * m1 + (1 - b1) * g
            m2 = b2 * m2 + (1 - b2) * g * g
            mhat = m1 / (1 - b1**step)
            vhat = m2 / (1 - b2**step)
            new_p[k] = params[k] - cfg.lr * mhat / (jnp.sqrt(vhat) + eps)
            new_o[k] = (m1, m2)
        return new_p, new_o

    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    n = len(x)
    beta = cfg.beta_init
    integ = 0.0
    losses, betas = [], []
    step = 0
    for epoch in range(cfg.epochs):
        order = rng.permutation(n)
        epoch_loss = 0.0
        nb = 0
        for s in range(0, n - cfg.batch + 1, cfg.batch):
            idx = order[s : s + cfg.batch]
            key, sub = jax.random.split(key)
            step += 1
            (lv, mean_kl), grads = grad_fn(
                params, jnp.asarray(x[idx]), jnp.asarray(lab[idx]),
                jnp.float32(beta), sub,
            )
            params, opt = adam_step(params, opt, grads, jnp.float32(step))
            # PI controller on β: drive KL toward the setpoint (eq. 9's β(k)).
            err = float(mean_kl) - cfg.kl_setpoint
            integ = np.clip(integ + err, -200.0, 200.0)
            beta = float(
                np.clip(
                    beta + cfg.kp * err + cfg.ki * integ,
                    cfg.beta_min,
                    cfg.beta_max,
                )
            )
            epoch_loss += float(lv)
            nb += 1
        losses.append(epoch_loss / max(nb, 1))
        betas.append(beta)
    return TrainResult(params=params, mean=mean, std=std, losses=losses, betas=betas)


def make_scorer(result: TrainResult, cfg: VaeConfig, batch: int):
    """Deterministic scorer for AOT lowering.

    ``score(m_raw f32[batch, F]) -> f32[batch, F+1]``: columns ``[:F]`` are
    the de-normalized reconstruction, column ``F`` is KL(q(z|m) ‖ p(z)).
    """
    p = result.params
    mean = jnp.asarray(result.mean, jnp.float32)
    std = jnp.asarray(result.std, jnp.float32)

    def score(m_raw):
        m = (m_raw - mean) / std
        mu, logvar = encode(p, m)
        recon = decode(p, mu)  # posterior mean, no sampling
        kl = kl_to_prior(mu, logvar)
        recon_raw = recon * std + mean
        return jnp.concatenate([recon_raw, kl[:, None]], axis=1)

    return score


def score_numpy(result: TrainResult, values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side scoring for tests: returns (kl, recon_raw)."""
    m = (values - result.mean) / result.std
    mu, logvar = encode(result.params, jnp.asarray(m, jnp.float32))
    recon = decode(result.params, mu)
    kl = kl_to_prior(mu, logvar)
    recon_raw = np.asarray(recon) * result.std + result.mean
    return np.asarray(kl), recon_raw
