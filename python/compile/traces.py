"""Synthetic labeled metric traces standing in for the paper's industrial data.

The paper's Table IV dataset: a chatbot service with 8 deployed LLMs × 2
replicas, metrics at 1-minute cadence for 4 weeks; first 2 weeks train the
detectors, last 2 weeks test them (1440·14·8·2 = 322 560 test points, 251
labeled anomalies). That data is proprietary, so we synthesize traces with
the same dimensionality, cadence, anomaly rarity and anomaly archetypes
(DESIGN.md §Substitutions):

* Base load is diurnal (morning/evening peaks) with weekly modulation and
  heteroscedastic noise; each service instance has its own capacity
  ``n_limit`` and execution-time profile.
* Metrics follow the Table II set through a small queueing identity:
  running = min(arriving·t_exec, max_num_seqs), pending accumulates the
  excess, finished tracks served load, GPU/memory utilization follow the
  running batch (KV-cache residency).
* Anomaly archetypes: **overload** (arrivals exceed capacity → pending
  ramps, latency inflates), **memleak** (memory drifts up independent of
  load), **stall** (finished collapses while arrivals stay normal — the
  "service down" mode of Fig. 1).

Deterministic for a given seed. The same CSV is consumed by the rust
detection baselines so every detector in Table IV sees identical data.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

# Metric column order — must match rust `metrics::COLUMNS`.
METRIC_NAMES = [
    "n_finished",  # n^f  finished requests / min
    "n_running",  # n^r  running requests (batch occupancy)
    "n_arriving",  # n^a  arriving requests / min
    "n_pending",  # n^p  queued requests
    "t_request",  # t^r  mean execution time per request (s)
    "mem_util",  # m^u  GPU memory utilization [0,1]
    "gpu_util",  # g^u  GPU compute utilization [0,1]
    "kv_util",  # KV-cache block utilization [0,1]
]
N_METRICS = len(METRIC_NAMES)

MINUTES_PER_DAY = 1440
N_SERVICES = 8
N_REPLICAS = 2
TRAIN_DAYS = 14
TEST_DAYS = 14


@dataclasses.dataclass
class TraceSet:
    """``values`` is [rows, N_METRICS]; rows ordered (day-minute, instance)."""

    values: np.ndarray
    labels: np.ndarray  # 1 = anomalous point
    split: np.ndarray  # 0 = train, 1 = test
    instance: np.ndarray  # instance id 0..15


def _diurnal(minutes: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Arrival intensity multiplier over the day, two peaks + noise."""
    t = (minutes % MINUTES_PER_DAY) / MINUTES_PER_DAY * 2 * np.pi
    base = 0.55 + 0.3 * np.sin(t - 2.0) + 0.18 * np.sin(2 * t + 0.7)
    week = 1.0 + 0.08 * np.sin(minutes / (7 * MINUTES_PER_DAY) * 2 * np.pi)
    return np.clip(base * week, 0.05, None)


def _instance_trace(
    inst: int,
    n_days: int,
    rng: np.random.Generator,
    anomaly_windows: List[Tuple[int, int, str]],
) -> Tuple[np.ndarray, np.ndarray]:
    n = n_days * MINUTES_PER_DAY
    minutes = np.arange(n)

    # Per-instance profile (device + model heterogeneity).
    n_limit = rng.uniform(4.0, 9.0)  # sustainable req/s → per-min scale
    max_seqs = rng.integers(16, 129)
    t_base = rng.uniform(2.0, 6.0)  # base execution seconds
    mem_base = rng.uniform(0.45, 0.65)

    load = _diurnal(minutes, rng) * n_limit * rng.uniform(0.5, 0.8)
    arriving = np.maximum(
        rng.poisson(np.maximum(load, 0.01) * 60.0) / 60.0, 0.0
    )  # req/s averaged per minute

    labels = np.zeros(n, dtype=np.int8)
    overload_boost = np.zeros(n)
    leak = np.zeros(n)
    stall = np.ones(n)
    for (start, dur, kind) in anomaly_windows:
        sl = slice(start, min(start + dur, n))
        labels[sl] = 1
        if kind == "overload":
            overload_boost[sl] = n_limit * rng.uniform(0.6, 1.2)
        elif kind == "memleak":
            leak[sl] = np.linspace(0.0, rng.uniform(0.25, 0.4), sl.stop - sl.start)
        elif kind == "stall":
            stall[sl] = rng.uniform(0.02, 0.12)

    arriving = arriving + overload_boost
    capacity = n_limit * stall
    finished = np.minimum(arriving, capacity)
    # queue accumulation: excess arrivals pend, drain at spare capacity
    pending = np.zeros(n)
    q = 0.0
    for i in range(n):
        q = max(0.0, q + (arriving[i] - capacity[i]) * 60.0)
        q = min(q, 4000.0)
        pending[i] = q
    congest = np.clip(pending / 60.0, 0.0, 8.0)
    t_req = t_base * (1.0 + 0.35 * congest) * (1.0 + rng.normal(0, 0.04, n))
    running = np.minimum(finished * t_req, float(max_seqs))
    kv_util = np.clip(running / max_seqs + rng.normal(0, 0.02, n), 0.0, 1.0)
    mem = np.clip(
        mem_base + 0.3 * kv_util + leak + rng.normal(0, 0.015, n), 0.0, 1.0
    )
    gpu = np.clip(
        0.15 + 0.8 * (running / max_seqs) * stall + rng.normal(0, 0.04, n),
        0.0,
        1.0,
    )

    vals = np.stack(
        [finished * 60.0, running, arriving * 60.0, pending, t_req, mem, gpu, kv_util],
        axis=1,
    ).astype(np.float32)
    return vals, labels


def _sample_windows(
    n_days: int,
    rng: np.random.Generator,
    n_windows: int,
) -> List[Tuple[int, int, str]]:
    kinds = ["overload", "memleak", "stall"]
    out = []
    for _ in range(n_windows):
        start = int(rng.integers(60, n_days * MINUTES_PER_DAY - 120))
        dur = int(rng.integers(5, 17))
        out.append((start, dur, kinds[int(rng.integers(0, len(kinds)))]))
    return out


def generate(seed: int = 7) -> TraceSet:
    """Build the full 4-week, 16-instance labeled trace set."""
    rng = np.random.default_rng(seed)
    n_days = TRAIN_DAYS + TEST_DAYS
    all_vals, all_labels, all_split, all_inst = [], [], [], []
    for inst in range(N_SERVICES * N_REPLICAS):
        # Sparse anomalies: ~1 window in train (semi-supervision labels),
        # ~1 window in test; totals land near the paper's 251 test points.
        n_train_w = int(rng.integers(0, 3))
        n_test_w = int(rng.integers(1, 3))
        train_w = [
            (s, d, k)
            for (s, d, k) in _sample_windows(TRAIN_DAYS, rng, n_train_w)
        ]
        test_w = [
            (s + TRAIN_DAYS * MINUTES_PER_DAY, d, k)
            for (s, d, k) in _sample_windows(TEST_DAYS, rng, n_test_w)
        ]
        vals, labels = _instance_trace(inst, n_days, rng, train_w + test_w)
        split = np.zeros(len(vals), dtype=np.int8)
        split[TRAIN_DAYS * MINUTES_PER_DAY :] = 1
        all_vals.append(vals)
        all_labels.append(labels)
        all_split.append(split)
        all_inst.append(np.full(len(vals), inst, dtype=np.int16))
    return TraceSet(
        values=np.concatenate(all_vals),
        labels=np.concatenate(all_labels),
        split=np.concatenate(all_split),
        instance=np.concatenate(all_inst),
    )


def write_csv(ts: TraceSet, path: str) -> None:
    header = "instance,split,label," + ",".join(METRIC_NAMES)
    cols = np.column_stack(
        [ts.instance.astype(np.float64), ts.split, ts.labels, ts.values]
    )
    fmt = ["%d", "%d", "%d"] + ["%.6g"] * N_METRICS
    np.savetxt(path, cols, delimiter=",", header=header, comments="", fmt=fmt)


def train_test(ts: TraceSet):
    tr = ts.split == 0
    te = ts.split == 1
    return (
        ts.values[tr],
        ts.labels[tr],
        ts.values[te],
        ts.labels[te],
    )
