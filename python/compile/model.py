"""L2: tiny LLaMA-style causal LM served end-to-end by the rust engine.

Two entry points are AOT-lowered to HLO text (weights baked as constants):

* ``prefill(state, tokens[S], prompt_len, slot)`` — full causal forward of
  one prompt; writes the prompt's KV into batch slot ``slot`` of the shared
  cache and the last-token logits into the logits region.
* ``decode_step(state, tokens[B], seq_lens[B])`` — one autoregressive step
  for the whole running batch (continuous batching happens in rust: the
  engine fills/clears slots between steps). Uses the Pallas flash-decode
  attention kernel and fused SwiGLU kernel.

**State-carry layout.** Both functions map ``f32[STATE] -> f32[STATE]`` with
``STATE = B*V + KV_ELEMS`` (logits FIRST):

```
state[0 : B*V]   — logits scratch, shape [B, V]
state[B*V : ]    — KV cache, shape [L, 2, B, H, S, D] (0=key, 1=value)
```

A single (non-tuple) array output lets the rust runtime chain steps entirely
on-device via ``execute_b`` and read back only the ``B*V`` logits head with
``copy_raw_to_host_sync`` — the KV cache never crosses the host boundary on
the request path (EXPERIMENTS.md §Perf). Logits live at the *front* because
PJRT's ``CopyRawToHost`` takes a byte offset while the rust wrapper
bounds-checks in elements: only small offsets satisfy both conventions.

Positions use learned absolute embeddings (cache-friendly: cached K/V are
position-independent transforms, so slots can be filled in any order).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.decode_attention import decode_attention
from .kernels.fused_ffn import fused_ffn
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the served model. Defaults are the shipped artifact."""

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    max_seq: int = 128  # S: compiled KV capacity per sequence
    batch: int = 8  # B: compiled running-batch width (max_num_seqs upper bound)

    @property
    def kv_elems(self) -> int:
        return (
            self.n_layers * 2 * self.batch * self.n_heads * self.max_seq * self.head_dim
        )

    @property
    def logits_elems(self) -> int:
        return self.batch * self.vocab

    @property
    def state_elems(self) -> int:
        return self.kv_elems + self.logits_elems

    @property
    def param_count(self) -> int:
        per_layer = (
            4 * self.d_model * self.n_heads * self.head_dim  # q,k,v,o
            + 3 * self.d_model * self.d_ff  # gate, up, down
            + 2 * self.d_model  # two rmsnorm scales
        )
        return (
            self.vocab * self.d_model  # tied embed/unembed
            + self.max_seq * self.d_model  # learned positions
            + self.n_layers * per_layer
            + self.d_model  # final norm
        )


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic small-scale init (the serving paper never trains)."""
    rng = np.random.default_rng(seed)

    def mat(*shape, scale=None):
        scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
        return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)

    p: Dict[str, jnp.ndarray] = {
        "embed": mat(cfg.vocab, cfg.d_model, scale=0.02),
        "pos": mat(cfg.max_seq, cfg.d_model, scale=0.02),
        "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    hd = cfg.n_heads * cfg.head_dim
    for l in range(cfg.n_layers):
        p[f"l{l}.norm1"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"l{l}.norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"l{l}.wq"] = mat(cfg.d_model, hd)
        p[f"l{l}.wk"] = mat(cfg.d_model, hd)
        p[f"l{l}.wv"] = mat(cfg.d_model, hd)
        p[f"l{l}.wo"] = mat(hd, cfg.d_model)
        p[f"l{l}.wg"] = mat(cfg.d_model, cfg.d_ff)
        p[f"l{l}.wu"] = mat(cfg.d_model, cfg.d_ff)
        p[f"l{l}.wd"] = mat(cfg.d_ff, cfg.d_model)
    return p


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _unpack(state: jnp.ndarray, cfg: ModelConfig):
    logits = state[: cfg.logits_elems].reshape(cfg.batch, cfg.vocab)
    kv = state[cfg.logits_elems :].reshape(
        cfg.n_layers, 2, cfg.batch, cfg.n_heads, cfg.max_seq, cfg.head_dim
    )
    return kv, logits


def _pack(kv: jnp.ndarray, logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([logits.reshape(-1), kv.reshape(-1)])


def decode_step(
    state: jnp.ndarray,
    tokens: jnp.ndarray,
    seq_lens: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    interpret: bool = True,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """One autoregressive step for the running batch.

    ``seq_lens[b]`` is the number of tokens already cached for slot ``b``;
    the new token is written at that position. ``seq_lens[b] <= 0`` marks an
    inactive slot: its KV and logits rows are left untouched / zeroed.
    """
    kv, _ = _unpack(state, cfg)
    active = seq_lens > 0
    pos = jnp.clip(seq_lens, 0, cfg.max_seq - 1)

    x = params["embed"][tokens] + params["pos"][pos]  # [B, dm]
    x = jnp.where(active[:, None], x, 0.0)

    onehot = (
        jnp.arange(cfg.max_seq, dtype=jnp.int32)[None, :] == pos[:, None]
    ) & active[:, None]  # [B, S]
    oh = onehot.astype(x.dtype)[:, None, :, None]  # [B, 1, S, 1]

    new_kv_layers = []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.norm1"])
        q = (h @ params[f"l{l}.wq"]).reshape(cfg.batch, cfg.n_heads, cfg.head_dim)
        k = (h @ params[f"l{l}.wk"]).reshape(cfg.batch, cfg.n_heads, cfg.head_dim)
        v = (h @ params[f"l{l}.wv"]).reshape(cfg.batch, cfg.n_heads, cfg.head_dim)

        # Scatter this step's K/V into the cache at each slot's position.
        k_cache = kv[l, 0] * (1.0 - oh) + k[:, :, None, :] * oh  # [B,H,S,D]
        v_cache = kv[l, 1] * (1.0 - oh) + v[:, :, None, :] * oh
        new_kv_layers.append(jnp.stack([k_cache, v_cache]))

        attn_lens = jnp.where(active, pos + 1, 0)
        if use_pallas:
            att = decode_attention(
                q, k_cache, v_cache, attn_lens,
                block_k=min(64, cfg.max_seq), interpret=interpret,
            )
        else:
            att = ref.decode_attention_ref(q, k_cache, v_cache, attn_lens)
        x = x + att.reshape(cfg.batch, -1) @ params[f"l{l}.wo"]

        h2 = rmsnorm(x, params[f"l{l}.norm2"])
        if use_pallas:
            y = fused_ffn(
                h2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"],
                block_n=min(8, cfg.batch), block_f=128, interpret=interpret,
            )
        else:
            y = ref.fused_ffn_ref(
                h2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"]
            )
        x = x + y

    new_kv = jnp.stack(new_kv_layers)  # [L, 2, B, H, S, D]
    logits = rmsnorm(x, params["norm_f"]) @ params["embed"].T  # [B, V]
    logits = jnp.where(active[:, None], logits, 0.0)
    return _pack(new_kv, logits)


def prefill(
    state: jnp.ndarray,
    tokens: jnp.ndarray,
    prompt_len: jnp.ndarray,
    slot: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    interpret: bool = True,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Full causal forward of one prompt; fills batch slot ``slot``.

    ``tokens`` is ``[S]`` (padded), ``prompt_len`` scalar int32 in
    ``[1, S]``, ``slot`` scalar int32 in ``[0, B)``. Logits of the last real
    token land in logits row ``slot``; other rows are preserved.
    """
    kv, logits = _unpack(state, cfg)
    s = cfg.max_seq

    x = params["embed"][tokens] + params["pos"][jnp.arange(s)]  # [S, dm]

    seq_k = []
    seq_v = []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.norm1"])
        q = (h @ params[f"l{l}.wq"]).reshape(s, cfg.n_heads, cfg.head_dim)
        k = (h @ params[f"l{l}.wk"]).reshape(s, cfg.n_heads, cfg.head_dim)
        v = (h @ params[f"l{l}.wv"]).reshape(s, cfg.n_heads, cfg.head_dim)
        q, k, v = (t.transpose(1, 0, 2) for t in (q, k, v))  # [H, S, D]
        att = ref.full_attention_ref(q, k, v, prompt_len)  # [H, S, D]
        x = x + att.transpose(1, 0, 2).reshape(s, -1) @ params[f"l{l}.wo"]

        h2 = rmsnorm(x, params[f"l{l}.norm2"])
        if use_pallas:
            y = fused_ffn(
                h2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"],
                block_n=min(8, s), block_f=128, interpret=interpret,
            )
        else:
            y = ref.fused_ffn_ref(
                h2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"]
            )
        x = x + y
        seq_k.append(k)
        seq_v.append(v)

    # Zero out padding positions so stale values never leak into decode.
    valid = (jnp.arange(s)[None, :, None] < prompt_len).astype(x.dtype)
    seq_kv = jnp.stack(
        [jnp.stack([k * valid, v * valid]) for k, v in zip(seq_k, seq_v)]
    )  # [L, 2, H, S, D]
    new_kv = jax.lax.dynamic_update_slice(
        kv, seq_kv[:, :, None], (0, 0, slot, 0, 0, 0)
    )

    last = jnp.clip(prompt_len - 1, 0, s - 1)
    last_x = jax.lax.dynamic_slice(x, (last, 0), (1, cfg.d_model))  # [1, dm]
    row = rmsnorm(last_x, params["norm_f"]) @ params["embed"].T  # [1, V]
    new_logits = jax.lax.dynamic_update_slice(logits, row, (slot, 0))
    return _pack(new_kv, new_logits)


def full_forward_logits(
    tokens: jnp.ndarray,
    prompt_len: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> jnp.ndarray:
    """Reference: logits at every position of a single sequence ``[S, V]``.

    Used only by tests to validate prefill/decode cache equivalence.
    """
    s = tokens.shape[0]
    x = params["embed"][tokens] + params["pos"][jnp.arange(s)]
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.norm1"])
        q = (h @ params[f"l{l}.wq"]).reshape(s, cfg.n_heads, cfg.head_dim)
        k = (h @ params[f"l{l}.wk"]).reshape(s, cfg.n_heads, cfg.head_dim)
        v = (h @ params[f"l{l}.wv"]).reshape(s, cfg.n_heads, cfg.head_dim)
        q, k, v = (t.transpose(1, 0, 2) for t in (q, k, v))
        att = ref.full_attention_ref(q, k, v, prompt_len)
        x = x + att.transpose(1, 0, 2).reshape(s, -1) @ params[f"l{l}.wo"]
        h2 = rmsnorm(x, params[f"l{l}.norm2"])
        x = x + ref.fused_ffn_ref(
            h2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"]
        )
    return rmsnorm(x, params["norm_f"]) @ params["embed"].T


def make_entry_points(cfg: ModelConfig, params: Dict[str, jnp.ndarray]):
    """Weight-baked jittable callables for AOT lowering."""

    def decode_fn(state, tokens, seq_lens):
        return decode_step(state, tokens, seq_lens, params, cfg)

    def prefill_fn(state, tokens, prompt_len, slot):
        return prefill(state, tokens, prompt_len, slot, params, cfg)

    return decode_fn, prefill_fn
